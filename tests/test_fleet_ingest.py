"""Fleet ingest: offline spill-file merge is bit-equal to the offline
oracle; a real-socket 2-producer ingest reproduces the offline merge of the
same events; host provenance flows into text/json/chrome exporters."""
import json
import time

import numpy as np
import pytest

from repro.core import (EventLog, ProfileSession, SpillStore, detect_offline,
                        export, synthetic_log)
from repro.core.tracer import StackRegistry, TagRegistry
from repro.fleet import FleetSource, IngestServer, RemoteSink, attach_remote
from tests.test_tracer import FakeClock


def _write_spill(path, log, chunk_events=64):
    st = SpillStore(str(path), chunk_events=chunk_events)
    st.append_columns(log.times, log.workers, log.deltas, log.tags,
                      log.stacks)
    st.close()


def _merge_remapped(logs, offsets):
    """The oracle merge: concat with global worker ids, one stable lexsort
    with the shard tie-break keys (time, then DEACTIVATE first, then id)."""
    cols = [np.concatenate([l.times for l in logs]),
            np.concatenate([(l.workers + o).astype(np.int32)
                            for l, o in zip(logs, offsets)]),
            np.concatenate([l.deltas for l in logs]),
            np.concatenate([l.tags for l in logs]),
            np.concatenate([l.stacks for l in logs])]
    order = np.lexsort((cols[1], cols[2], cols[0]))
    return EventLog(*[c[order] for c in cols],
                    num_workers=sum(l.num_workers for l in logs))


def _ranked(rep):
    return [(rep.path_str(p), p.cmetric, p.slices) for p in rep.paths]


# ---------------------------------------------------------------------------
# acceptance: offline spill-file ingest, bit-equal to the merged oracle
# ---------------------------------------------------------------------------

def test_from_files_bit_equal_to_merged_detect_offline(tmp_path):
    rng = np.random.default_rng(0)
    nws = (3, 2, 4)
    logs, paths = [], []
    for i, nw in enumerate(nws):
        log = synthetic_log(rng, nw, 60)
        p = tmp_path / f"h{i}.spill"
        _write_spill(p, log)
        logs.append(log)
        paths.append(str(p))
    merged = _merge_remapped(logs, np.cumsum([0] + list(nws[:-1])))
    oracle = detect_offline(merged, TagRegistry(), StackRegistry(),
                            n_min=3.0)
    # worker counts are pre-scanned from the raw files; chunk size is
    # unrelated to the spill block size on purpose
    src = FleetSource.from_files(paths, chunk_events=97)
    assert src.num_workers == sum(nws)
    rep = ProfileSession(src, n_min=3.0).result()
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert rep.total_slices == oracle.total_slices
    assert rep.total_critical == oracle.total_critical
    assert rep.idle_time == oracle.idle_time
    assert rep.total_time == oracle.total_time
    assert _ranked(rep) == _ranked(oracle)
    np.testing.assert_array_equal(rep.critical_table.cm,
                                  oracle.critical_table.cm)
    np.testing.assert_array_equal(rep.critical_table.threads_av,
                                  oracle.critical_table.threads_av)
    np.testing.assert_array_equal(rep.critical_table.worker,
                                  oracle.critical_table.worker)
    # provenance: every worker is attributed to its source file's host
    assert rep.worker_hosts == ["h0"] * 3 + ["h1"] * 2 + ["h2"] * 4
    assert rep.hosts == ["h0", "h1", "h2"]


def test_from_files_background_worker_and_full_log(tmp_path):
    rng = np.random.default_rng(5)
    logs, paths = [], []
    for i in range(3):
        log = synthetic_log(rng, 2, 40)
        _write_spill(tmp_path / f"f{i}.spill", log, chunk_events=32)
        logs.append(log)
        paths.append(str(tmp_path / f"f{i}.spill"))
    merged = _merge_remapped(logs, [0, 2, 4])
    # full_log materializes the same merge
    full = FleetSource.from_files(paths).full_log()
    for col in ("times", "workers", "deltas", "tags", "stacks"):
        np.testing.assert_array_equal(getattr(full, col), getattr(merged, col))
    # background worker path (start() then result())
    s = ProfileSession(FleetSource.from_files(paths), n_min=1.5)
    s.start()
    rep = s.result()
    oracle = detect_offline(merged, TagRegistry(), StackRegistry(), 1.5)
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert rep.total_slices == oracle.total_slices


def test_from_files_clock_offsets_normalize(tmp_path):
    """A host whose clock runs 5ms ahead is corrected by its declared
    offset: the report equals the one from aligned captures."""
    rng = np.random.default_rng(9)
    a = synthetic_log(rng, 2, 50)
    b = synthetic_log(rng, 2, 50)
    skew = 5_000_000
    b_skewed = EventLog(b.times + skew, b.workers, b.deltas, b.tags,
                        b.stacks, b.num_workers)
    _write_spill(tmp_path / "a.spill", a)
    _write_spill(tmp_path / "b.spill", b_skewed)
    src = FleetSource.from_files(
        [str(tmp_path / "a.spill"), str(tmp_path / "b.spill")],
        clock_offsets_ns=[0, -skew])
    rep = ProfileSession(src, n_min=2.0).result()
    oracle = detect_offline(_merge_remapped([a, b], [0, 2]),
                            TagRegistry(), StackRegistry(), 2.0)
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert rep.total_slices == oracle.total_slices
    assert _ranked(rep) == _ranked(oracle)


# ---------------------------------------------------------------------------
# acceptance: real-socket 2-producer ingest == offline merge of same events
# ---------------------------------------------------------------------------

def _produce(server_addr, host_index):
    """One producer host: live session + RemoteSink, deterministic clock."""
    clk = FakeClock()
    clk.t = host_index * 137            # interleave timestamps across hosts
    s = ProfileSession(n_min=2.0, clock=clk, drain_interval=0.001)
    wids = [s.register_worker(f"t{i}") for i in range(2)]
    sink = attach_remote(s, server_addr, host_id=f"host{host_index}",
                         clock_offset_ns=0)
    return s, wids, clk, sink


def test_socket_two_producer_ingest_matches_offline_merge():
    server = IngestServer()
    server.start()
    fleet_sess = ProfileSession(server.source, n_min=2.0)
    fleet_sess.start()
    try:
        # attach sequentially: host index (== worker-offset order) follows
        # HELLO arrival, so registration order must be pinned for the
        # oracle comparison below
        prods = []
        for hi in range(2):
            prods.append(_produce(server.address, hi))
            deadline = time.time() + 5
            while (server.stats()["hosts"] < hi + 1
                   and time.time() < deadline):
                time.sleep(0.01)
        assert server.stats()["hosts"] == 2, server.stats()
        assert [h.host_id for h in server.source.hosts] == ["host0",
                                                            "host1"]

        logs = []
        for (s, wids, clk, sink) in prods:
            with s.running():
                for _ in range(250):
                    s.begin(wids[0], "step")
                    clk.advance(1000)
                    s.begin(wids[1], "io")
                    clk.advance(1000)
                    s.end(wids[1])
                    clk.advance(700)
                    s.end(wids[0])
                    clk.advance(300)
            s.result()
            logs.append((s.freeze(), s.tags, s.stacks))
        for (_, _, _, sink) in prods:
            sink.close()
            assert not sink.failed and sink.dropped_chunks == 0
        assert server.wait_idle(10), server.stats()
        rep = fleet_sess.result()
    finally:
        server.close()

    # oracle: remap each producer's frozen log into one shared registry,
    # concat with global worker ids, sort with the tie-break keys
    otags, ostacks = TagRegistry(), StackRegistry()
    remapped = []
    for (log, tags, stacks) in logs:
        tmap = np.asarray([otags.intern(n, loc) for n, loc in
                           zip(tags.names, tags.locations)], np.int32)
        smap = np.asarray(
            [ostacks.intern(tuple(int(tmap[t]) for t in p))
             for p in stacks.paths], np.int32)
        g = log.tags.copy()
        v = g >= 0
        g[v] = tmap[g[v]]
        st = log.stacks.copy()
        v = st >= 0
        st[v] = smap[st[v]]
        remapped.append(EventLog(log.times, log.workers, log.deltas, g, st,
                                 log.num_workers))
    merged = _merge_remapped(remapped, [0, 2])
    oracle = detect_offline(merged, otags, ostacks, n_min=2.0)

    assert server.stats()["proto_errors"] == 0
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert rep.total_slices == oracle.total_slices
    assert rep.total_critical == oracle.total_critical
    assert rep.idle_time == oracle.idle_time
    assert _ranked(rep) == _ranked(oracle)
    assert rep.worker_hosts == ["host0", "host0", "host1", "host1"]
    assert rep.worker_names[0] == "host0/t0"


# ---------------------------------------------------------------------------
# exporters render host lanes
# ---------------------------------------------------------------------------

def _fleet_report(tmp_path):
    rng = np.random.default_rng(3)
    logs, paths = [], []
    for i in range(2):
        log = synthetic_log(rng, 2, 30)
        _write_spill(tmp_path / f"e{i}.spill", log)
        logs.append(log)
        paths.append(str(tmp_path / f"e{i}.spill"))
    s = ProfileSession(FleetSource.from_files(paths), n_min=2.0)
    rep = s.result()
    full = FleetSource.from_files(paths).full_log()
    return s, rep, full


def test_text_and_json_exporters_render_host_lanes(tmp_path):
    s, rep, _ = _fleet_report(tmp_path)
    txt = s.export("text", max_paths=1)
    assert "per-host CMetric" in txt
    assert "e0" in txt and "e1" in txt
    d = json.loads(s.export("json"))
    assert d["schema_version"] >= 3
    assert d["worker_hosts"] == ["e0", "e0", "e1", "e1"]
    assert set(d["per_host"]) == {"e0", "e1"}
    assert d["per_host"]["e0"]["workers"] == 2
    ph = rep.per_host()
    assert abs(sum(h["cmetric_s"] for h in ph.values())
               - float(rep.per_worker.sum())) < 1e-12


def test_chrome_exporter_renders_host_process_lanes(tmp_path):
    _, rep, full = _fleet_report(tmp_path)
    trace = json.loads(export(rep, "chrome", log=full))
    procs = {e["pid"]: e["args"]["name"] for e in trace["traceEvents"]
             if e.get("name") == "process_name"}
    assert procs[0] == "e0" and procs[1] == "e1"
    # workers of host e1 (global ids 2,3) live in pid 1
    span_pids = {e["tid"]: e["pid"] for e in trace["traceEvents"]
                 if e.get("ph") == "X" and e["name"] != "CRITICAL"}
    for tid, pid in span_pids.items():
        assert pid == (0 if tid < 2 else 1)


def test_single_host_reports_unchanged(tmp_path):
    """No worker_hosts => no host lanes anywhere (back-compat)."""
    rng = np.random.default_rng(1)
    log = synthetic_log(rng, 4, 30)
    s = ProfileSession.offline(log, n_min=2.0)
    rep = s.result()
    assert rep.worker_hosts is None and rep.per_host() == {}
    assert "per-host CMetric" not in s.export("text")
    assert "worker_hosts" not in json.loads(s.export("json"))


# ---------------------------------------------------------------------------
# transport robustness
# ---------------------------------------------------------------------------

def test_remote_exporter_lazy_registration():
    """session.export("remote", ...) resolves through the lazy registry
    and fails cleanly without addr."""
    from repro.core.exporters import get_exporter
    exp = get_exporter("remote")
    assert "subscription" in exp.capabilities
    s = ProfileSession(n_min=1.0, clock=FakeClock())
    with pytest.raises(ValueError):
        s.export("remote")          # no addr


def test_remote_exporter_attaches_sink():
    server = IngestServer()
    server.start()
    try:
        clk = FakeClock()
        s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
        w = s.register_worker("w")
        sink = s.export("remote", addr=server.address, host_id="solo",
                        clock_offset_ns=0)
        assert isinstance(sink, RemoteSink)
        assert sink in s.tracer.sinks
        for _ in range(20):
            s.begin(w, "x")
            clk.advance(1000)
            s.end(w)
            clk.advance(500)
        s.result()                  # close() flushes attached sinks
        sink.close()
        assert sink.rows_sent == 40
        deadline = time.time() + 5
        while (server.source.stats()["rows_in"] < 40
               and time.time() < deadline):
            time.sleep(0.01)
        assert server.source.stats()["rows_in"] == 40
    finally:
        server.close()


def test_sink_fails_closed_when_server_unreachable():
    sink = RemoteSink(("127.0.0.1", 1), "nope", max_reconnects=2,
                      reconnect_delay=0.01, connect_timeout=0.2)
    sink.start()
    z = [np.zeros(1, dt) for dt in
         (np.int64, np.int32, np.int8, np.int32, np.int32)]
    sink.append_columns(*z)
    deadline = time.time() + 10
    while not sink.failed and time.time() < deadline:
        time.sleep(0.01)
    assert sink.failed and sink.send_errors >= 1
    # once failed, appends drop (never block the tracer) and flush returns
    sink.append_columns(*z)
    assert sink.dropped_chunks >= 1
    assert sink.flush(timeout=1.0) is False or sink.failed
    sink.close(timeout=1.0)


def test_backpressure_drop_mode_counts(tmp_path):
    """drop_when_full=True sheds chunks instead of stalling the drain."""
    sink = RemoteSink(("127.0.0.1", 1), "shed", max_buffer_chunks=1,
                      drop_when_full=True, max_reconnects=10**6,
                      reconnect_delay=5.0, connect_timeout=0.05)
    # no start(): the queue can never drain, so the second append must drop
    z = [np.zeros(1, dt) for dt in
         (np.int64, np.int32, np.int8, np.int32, np.int32)]
    sink.append_columns(*z)
    sink.append_columns(*z)
    assert sink.dropped_chunks == 1


def test_ingest_server_dedups_retransmitted_chunks():
    """A chunk resent after a flaky send (same seq) must fold once: the
    server drops already-seen sequence numbers, so the reconnect
    retransmit path is exactly-once."""
    import socket as socket_mod
    from repro.fleet import wire
    server = IngestServer()
    server.start()
    try:
        sock = socket_mod.create_connection(server.address, timeout=5)
        f = sock.makefile("rwb")
        f.write(wire.encode_hello("dup-host", 1, ["w0"], t_client_ns=0,
                                  clock_offset_ns=0))
        f.flush()
        kind, payload = wire.read_frame(f)
        assert kind == wire.WELCOME
        epoch = wire.decode_json(payload)["epoch"]
        cols = (np.asarray([10, 20], np.int64), np.zeros(2, np.int32),
                np.asarray([1, -1], np.int8), np.full(2, -1, np.int32),
                np.full(2, -1, np.int32))
        chunk = wire.encode_chunk(0, wire.MERGED_SHARD, epoch, 0, *cols)
        f.write(chunk)
        f.write(chunk)              # retransmit, same seq
        f.write(wire.encode_bye(rows_sent=2, chunks_sent=1))
        f.flush()
        deadline = time.time() + 5
        while (not server.stats()["duplicate_chunks"]
               and time.time() < deadline):
            time.sleep(0.01)
        st = server.stats()
        assert st["duplicate_chunks"] == 1
        assert st["rows_in"] == 2   # folded once, not twice
        f.close()
        sock.close()
    finally:
        server.close()


def test_producer_restart_with_stable_host_id_not_deduped():
    """A restarted producer (fresh RemoteSink, same host_id) carries a new
    instance nonce: the server resets the seq-dedup floor instead of
    dropping the new capture's chunks as retransmits."""
    server = IngestServer()
    server.start()
    try:
        for run in range(2):
            clk = FakeClock()
            clk.t = run * 10_000_000
            s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
            w = s.register_worker("w")
            sink = attach_remote(s, server.address, host_id="stable",
                                 clock_offset_ns=0)
            for _ in range(10):
                s.begin(w, "x")
                clk.advance(1000)
                s.end(w)
                clk.advance(1000)
            s.result()
            sink.close()
            assert sink.rows_sent == 20
        assert server.wait_idle(10), server.stats()
        st = server.stats()
        assert st["hosts"] == 1
        assert st["duplicate_chunks"] == 0
        assert st["rows_in"] == 40          # both captures ingested
    finally:
        server.close()


def test_ingest_server_measures_clock_offset():
    """clock_offset_ns=None in HELLO: the server derives the offset from
    the handshake and applies it to ingested times."""
    server = IngestServer(clock=lambda: 1_000_000)
    server.start()
    try:
        clk = FakeClock()
        clk.t = 500                       # producer clock epoch
        s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
        w = s.register_worker("w")
        sink = attach_remote(s, server.address, host_id="skewed",
                             clock_offset_ns=None)
        deadline = time.time() + 5
        while not server.stats()["hosts"] and time.time() < deadline:
            time.sleep(0.01)
        measured = server.source.hosts[0].clock_offset_ns
        # t_client was sampled at 500 on the fake clock
        assert measured == 1_000_000 - 500
        s.begin(w, "x")
        clk.advance(100)
        s.end(w)
        s.result()
        sink.close()
        assert server.wait_idle(5)
        fleet_rep = ProfileSession(server.source, n_min=1.0).result()
        assert fleet_rep.total_slices == 1
        h = server.source.hosts[0]
        assert h.rows_in == 2
    finally:
        server.close()
