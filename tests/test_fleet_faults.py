"""Deterministic fault injection (repro.fleet.faults.FaultPlan).

Every chaos-bench failure mode is reproduced here as an ordinary unit
test: connection drops, torn (truncated) frames, header corruption,
connect refusal (partitions), and disk-full on either journal — each
asserting the recovery contract from the failure-modes matrix in
``repro/fleet/__init__.py``.
"""
import errno
import io
import time

import numpy as np
import pytest

from repro.core import ProfileSession, SpillStore, detect_offline
from repro.fleet import FaultPlan, FleetSource, IngestServer, attach_remote
from tests.test_tracer import FakeClock


def _wait(cond, timeout=5.0):
    deadline = time.time() + timeout
    while not cond() and time.time() < deadline:
        time.sleep(0.01)
    assert cond()


def _stream_spans(s, w, clk, n, tag="x"):
    for _ in range(n):
        s.begin(w, tag)
        clk.advance(1000)
        s.end(w)
        clk.advance(500)


def _ranked(rep):
    return [(rep.path_str(p), p.cmetric, p.slices) for p in rep.paths]


def _assert_equals_journals(rep, fleet_dir):
    src = FleetSource.from_fleet_dir(fleet_dir)
    oracle = detect_offline(src.full_log(), src.tags, src.stacks, n_min=1.0)
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert rep.total_slices == oracle.total_slices
    assert _ranked(rep) == _ranked(oracle)


# ---------------------------------------------------------------------------
# FaultPlan unit semantics (no sockets)
# ---------------------------------------------------------------------------

def test_rules_fire_on_exact_frames_and_log_events():
    plan = FaultPlan(seed=7)
    plan.drop("h", frame=2).corrupt("h", frame=1, offset=2)
    raw = io.BytesIO()
    f = plan.wrap_producer("h", raw, conn=0)
    f.write(b"frame0-ok")
    f.write(b"frame1-corrupt-me")
    with pytest.raises(ConnectionResetError):
        f.write(b"frame2-dropped")
    data = raw.getvalue()
    assert data.startswith(b"frame0-ok")
    # corruption flipped exactly byte 2 of frame 1, nothing else
    orig = b"frame1-corrupt-me"
    got = data[len(b"frame0-ok"):]
    assert got[2] == orig[2] ^ 0xFF
    assert got[:2] + got[3:] == orig[:2] + orig[3:]
    assert [(h, k) for h, k, _ in plan.events] == [("h", "corrupt"),
                                                  ("h", "drop")]


def test_truncate_writes_prefix_then_dies():
    plan = FaultPlan()
    plan.truncate("h", frame=1, keep=4)
    raw = io.BytesIO()
    f = plan.wrap_producer("h", raw)
    f.write(b"AAAA-first")
    with pytest.raises(ConnectionResetError):
        f.write(b"BBBBBBBB-second")
    assert raw.getvalue() == b"AAAA-firstBBBB"     # torn frame on the wire


def test_refuse_connect_budget_and_conn_indices():
    plan = FaultPlan()
    plan.refuse_connect("h", times=2)
    for _ in range(2):
        with pytest.raises(ConnectionRefusedError):
            plan.connect("h")
    assert plan.connect("h") == 0       # first SUCCESSFUL dial is conn 0
    assert plan.connect("h") == 1
    assert plan.connect("other") == 0   # per-host counters


def test_disk_full_triggers_at_block_then_recovers(tmp_path):
    plan = FaultPlan()
    plan.disk_full("h", at_block=2, failures=2)
    st = plan.wrap_journal("h", SpillStore(str(tmp_path / "j.spill")))
    cols = (np.array([1], np.int64), np.zeros(1, np.int32),
            np.ones(1, np.int8), np.zeros(1, np.int32),
            np.full(1, -1, np.int32))
    assert st.append_block(*cols) == 0
    assert st.append_block(*cols) == 1
    for _ in range(2):                  # budget of 2 ENOSPC failures
        with pytest.raises(OSError) as ei:
            st.append_block(*cols)
        assert ei.value.errno == errno.ENOSPC
    assert st.append_block(*cols) == 2  # disk "recovered"
    st.close()


def test_schedule_fires_each_threshold_once_in_order():
    plan = FaultPlan()
    plan.schedule("kill", [3, 5])
    fired = [step for step in range(8) if plan.due("kill", step)]
    assert fired == [3, 5]
    assert not plan.due("kill", 99)     # exhausted


def test_slow_applies_to_every_frame():
    plan = FaultPlan()
    plan.slow("h", per_frame=0.01)
    f = plan.wrap_producer("h", io.BytesIO())
    t0 = time.perf_counter()
    for _ in range(3):
        f.write(b"x")
    assert time.perf_counter() - t0 >= 0.03


# ---------------------------------------------------------------------------
# end-to-end recovery contracts (real sockets, scripted faults)
# ---------------------------------------------------------------------------

def _run_faulted_capture(tmp_path, plan, *, rounds=6, spans=5,
                         server_kw=None, sink_kw=None):
    """One journaled producer streams `rounds` snapshot-bounded chunks
    through `plan`; returns (report, server_stats, sink, fleet_dir)."""
    fleet_dir = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=fleet_dir, **(server_kw or {}))
    server.start()
    fleet_sess = ProfileSession(server.source, n_min=1.0)
    fleet_sess.start()
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, server.address, host_id="h", clock_offset_ns=0,
                         journal=str(tmp_path / "h.journal"),
                         reconnect_delay=0.01, heartbeat_interval=None,
                         fault_plan=plan, **(sink_kw or {}))
    try:
        for _ in range(rounds):
            _stream_spans(s, w, clk, spans)
            s.snapshot()                # chunk boundary: deterministic seqs
        s.result()
        sink.close()
        assert server.wait_idle(10), server.stats()
        rep = fleet_sess.result()
        st = server.stats()
    finally:
        fleet_sess.stop()
        server.close()
    return rep, st, sink, fleet_dir


def test_connection_drop_replays_with_zero_loss(tmp_path):
    plan = FaultPlan()
    plan.drop("h", frame=4, conn=0)     # mid-stream reset
    rep, st, sink, fleet_dir = _run_faulted_capture(tmp_path, plan)
    assert ("h", "drop") in [(h, k) for h, k, _ in plan.events]
    assert sink.reconnects >= 1
    assert not sink.failed, sink.last_error
    assert st["lost_chunks"] == 0, st
    assert st["rows_in"] == 60          # 6 rounds * 5 spans * 2 events
    assert rep.total_slices == 30
    _assert_equals_journals(rep, fleet_dir)


def test_corrupt_frame_is_detected_then_replayed(tmp_path):
    plan = FaultPlan()
    plan.corrupt("h", frame=3, conn=0)  # schema-version byte flip
    # the server rejects frame 3 and closes; the producer only observes
    # the RST on a LATER write — stall one so the reset surfaces
    # mid-stream (deterministically) instead of racing the BYE
    plan.stall("h", frame=5, seconds=0.3, conn=0)
    rep, st, sink, fleet_dir = _run_faulted_capture(tmp_path, plan)
    assert ("h", "corrupt") in [(h, k) for h, k, _ in plan.events]
    assert st["proto_errors"] >= 1, st  # detected, not folded
    assert st["lost_chunks"] == 0, st
    assert rep.total_slices == 30
    _assert_equals_journals(rep, fleet_dir)


def test_truncated_frame_torn_on_wire_then_replayed(tmp_path):
    plan = FaultPlan()
    plan.truncate("h", frame=4, keep=6, conn=0)
    rep, st, sink, fleet_dir = _run_faulted_capture(tmp_path, plan)
    assert ("h", "truncate") in [(h, k) for h, k, _ in plan.events]
    assert st["lost_chunks"] == 0, st
    assert st["duplicate_chunks"] == 0, st
    assert rep.total_slices == 30
    _assert_equals_journals(rep, fleet_dir)


def test_partition_drop_then_refuse_recovers(tmp_path):
    plan = FaultPlan()
    plan.drop("h", frame=5, conn=0)
    plan.refuse_connect("h", times=3)   # bounded partition
    rep, st, sink, fleet_dir = _run_faulted_capture(
        tmp_path, plan,
        sink_kw=dict(backoff_max=0.05, backoff_seed=1, max_reconnects=64))
    refusals = sum(1 for _, k, _ in plan.events if k == "refuse")
    assert refusals == 3
    assert not sink.failed
    assert st["lost_chunks"] == 0, st
    assert rep.total_slices == 30
    _assert_equals_journals(rep, fleet_dir)


def test_producer_disk_full_sheds_chunk_whole(tmp_path):
    """Producer journal ENOSPC: the chunk is dropped BEFORE it consumes a
    seq — visible as journal_errors/dropped_chunks, absent from BOTH the
    live fold and the journals, so union equality still holds."""
    plan = FaultPlan()
    plan.disk_full("h", at_block=2, failures=1)
    rep, st, sink, fleet_dir = _run_faulted_capture(tmp_path, plan)
    assert sink.journal_errors == 1
    assert sink.dropped_chunks == 1
    assert not sink.failed
    assert st["lost_chunks"] == 0, st       # dropped != lost: no seq gap
    assert st["rows_in"] == 50              # one 10-row chunk shed
    assert rep.total_slices == 25
    _assert_equals_journals(rep, fleet_dir)


def test_server_disk_full_refuses_chunk_and_replay_recovers(tmp_path):
    """Server journal ENOSPC: the chunk is REFUSED (no commit, connection
    closed); once the disk recovers the reconnect replay re-delivers it —
    recovered, not lost."""
    plan = FaultPlan()
    # the refusal closes the connection server-side; stall a later frame
    # so the producer observes the reset mid-stream and replays
    plan.stall("h", frame=6, seconds=0.3, conn=0)
    server_plan = FaultPlan()
    server_plan.disk_full("h", at_block=2, failures=1)
    rep, st, sink, fleet_dir = _run_faulted_capture(
        tmp_path, plan, server_kw=dict(fault_plan=server_plan))
    assert ("h", "disk_full") in [(h, k) for h, k, _ in server_plan.events]
    assert st["journal_errors"] == 1, st
    assert st["lost_chunks"] == 0, st
    assert st["duplicate_chunks"] == 0, st
    assert st["rows_in"] == 60              # everything arrived in the end
    assert rep.total_slices == 30
    _assert_equals_journals(rep, fleet_dir)
