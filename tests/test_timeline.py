"""Chrome-trace export."""
import json

from repro.core.timeline import dump_chrome_trace, to_chrome_trace
from tests.test_detector import _bottleneck_trace


def test_chrome_trace_roundtrip(tmp_path):
    tr, clk, w = _bottleneck_trace()
    path = str(tmp_path / "trace.json")
    dump_chrome_trace(tr, path)
    d = json.load(open(path))
    evs = d["traceEvents"]
    spans = [e for e in evs if e.get("ph") == "X" and e["pid"] == 0]
    crits = [e for e in evs if e.get("ph") == "X" and e["pid"] == 1]
    names = [e for e in evs if e.get("ph") == "M"]
    assert len(spans) == 24              # every completed slice
    assert len(crits) == 8               # the critical overlay
    assert any(n["args"]["name"] == "w2" for n in names
               if n["name"] == "thread_name")
    assert all(e["dur"] >= 0 for e in spans)
    top = max(crits, key=lambda e: e["args"]["cmetric_ms"])
    assert abs(top["args"]["cmetric_ms"] - 5.0) < 1e-6
