"""Chrome-trace export."""
import json

from repro.core.timeline import dump_chrome_trace, to_chrome_trace
from tests.test_detector import _bottleneck_trace
from tests.test_tracer import FakeClock


def test_chrome_trace_roundtrip(tmp_path):
    tr, clk, w = _bottleneck_trace()
    path = str(tmp_path / "trace.json")
    dump_chrome_trace(tr, path)
    d = json.load(open(path))
    evs = d["traceEvents"]
    spans = [e for e in evs if e.get("ph") == "X" and e["pid"] == 0]
    crits = [e for e in evs if e.get("ph") == "X" and e["pid"] == 1]
    names = [e for e in evs if e.get("ph") == "M"]
    assert len(spans) == 24              # every completed slice
    assert len(crits) == 8               # the critical overlay
    assert any(n["args"]["name"] == "w2" for n in names
               if n["name"] == "thread_name")
    assert all(e["dur"] >= 0 for e in spans)
    top = max(crits, key=lambda e: e["args"]["cmetric_ms"])
    assert abs(top["args"]["cmetric_ms"] - 5.0) < 1e-6


def test_chrome_trace_invariant_to_drain_schedule():
    """Satellite: the exported trace from the *sharded* tracer is identical
    no matter when drains (sync/autoflush) happen mid-capture — the trace
    is a pure function of the captured events, not of the flush schedule."""
    from repro.core import Tracer

    def drive(sync_every):
        clk = FakeClock()
        tr = Tracer(n_min=1.9, clock=clk)
        w = [tr.register_worker(f"w{i}") for i in range(3)]
        for rep in range(12):
            tr.begin(w[0], "par")
            tr.begin(w[1], "par")
            clk.advance(2_000_000)
            tr.end(w[0])
            tr.end(w[1])
            tr.begin(w[2], "io_phase")
            clk.advance(5_000_000)
            tr.end(w[2])
            if sync_every and rep % sync_every == 0:
                tr.sync()               # mid-capture drain
        return to_chrome_trace(tr.freeze(), tag_names=list(tr.tags.names),
                               worker_names=tr.worker_names(),
                               critical=tr.critical)

    baseline = drive(sync_every=0)      # single drain at freeze()
    assert drive(sync_every=1) == baseline
    assert drive(sync_every=3) == baseline
    assert drive(sync_every=5) == baseline
    # sanity: the trace isn't trivially empty
    evs = json.loads(baseline)["traceEvents"]
    assert sum(e.get("ph") == "X" for e in evs) == 12 * 3 + 12


def test_chrome_trace_invariant_under_autoflush_pressure():
    """Tiny shards force drains at arbitrary points inside the schedule;
    the trace must still equal the unpressured capture's."""
    from repro.core import Tracer

    def drive(capacity):
        clk = FakeClock()
        tr = Tracer(n_min=0.0, capacity=capacity, clock=clk)
        w = tr.register_worker("w")
        for i in range(64):
            tr.begin(w, "x")
            clk.advance(1_000)
            tr.end(w)
            clk.advance(100)
        return to_chrome_trace(tr.freeze(), tag_names=list(tr.tags.names),
                               worker_names=tr.worker_names(),
                               critical=tr.critical)

    assert drive(capacity=8) == drive(capacity=1 << 16)
