"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # `python -m pytest` from the repo root
    from tests.conftest import given, settings, st
except ImportError:                    # plain `pytest` (tests/ on sys.path)
    from conftest import given, settings, st

from repro.core import compute, compute_numpy, synthetic_log
from repro.kernels import ops, ref


@pytest.mark.parametrize("e", [1, 7, 128, 255, 256, 1000, 5000])
@pytest.mark.parametrize("block", [128, 512, 2048])
def test_fold_shapes(e, block):
    rng = np.random.default_rng(e + block)
    # random alternating-ish stream (not necessarily well-formed; the fold
    # itself only needs deltas)
    deltas = rng.choice([-1, 1], size=e).astype(np.int32)
    # keep count non-negative like a real stream
    deltas = np.abs(deltas) * (np.cumsum(deltas) > -5) * deltas
    t = np.sort(rng.random(e)).astype(np.float32)
    dt = np.concatenate([np.diff(t), [0.0]]).astype(np.float32)
    n_r, g_r, tot_r, idle_r, cnt_r = ref.fold_ref(jnp.asarray(dt),
                                                  jnp.asarray(deltas))
    n_k, g_k, tot_k, idle_k, cnt_k = ops.cmetric_fold(jnp.asarray(t),
                                                      jnp.asarray(deltas),
                                                      block=block)
    np.testing.assert_array_equal(np.asarray(n_r), np.asarray(n_k))
    np.testing.assert_allclose(np.asarray(g_r), np.asarray(g_k), rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(float(tot_r), float(tot_k), rtol=1e-5)
    np.testing.assert_allclose(float(idle_r), float(idle_k), rtol=1e-5,
                               atol=1e-7)
    assert float(cnt_r) == float(cnt_k) == float(np.sum(deltas))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 10), st.integers(1, 40), st.integers(0, 10_000))
def test_pallas_backend_matches_numpy(num_workers, slices, seed):
    rng = np.random.default_rng(seed)
    log = synthetic_log(rng, num_workers, slices)
    a = compute_numpy(log)
    b = compute(log, backend="pallas")
    np.testing.assert_allclose(a.per_worker, b.per_worker, rtol=1e-4,
                               atol=1e-6)
    assert a.num_slices == b.num_slices


@pytest.mark.parametrize("s,k", [(1, 4), (100, 17), (1024, 128),
                                 (5000, 1000), (333, 64)])
def test_hist_shapes(s, k):
    rng = np.random.default_rng(s * k)
    tags = jnp.asarray(rng.integers(-2, k, size=s), jnp.int32)
    w = jnp.asarray(rng.random(s), jnp.float32)
    c_r = ref.hist_ref(tags, k)
    w_r = ref.weighted_hist_ref(tags, w, k)
    c_k, w_k = ops.tag_histogram(tags, w, num_bins=k, block=256)
    np.testing.assert_array_equal(np.asarray(c_r), np.asarray(c_k))
    np.testing.assert_allclose(np.asarray(w_r), np.asarray(w_k), rtol=1e-4,
                               atol=1e-4)


def test_hist_default_weights():
    tags = jnp.asarray([0, 1, 1, 2, -1, 2, 2], jnp.int32)
    c, w = ops.tag_histogram(tags, num_bins=3)
    np.testing.assert_array_equal(np.asarray(c), [1, 2, 3])
    np.testing.assert_allclose(np.asarray(w), [1, 2, 3])


def test_fold_large_stream_blocked_equals_unblocked():
    rng = np.random.default_rng(0)
    log = synthetic_log(rng, 32, 500)   # 32k events
    t = jnp.asarray(log.slice_seconds(), jnp.float32)
    d = jnp.asarray(log.deltas, jnp.int32)
    outs = [ops.cmetric_fold(t, d, block=b) for b in (256, 4096)]
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-7)


def test_fold_kernel_carry_resume_equals_whole():
    """The fold kernel's (count, gcm, idle) carry stitches two calls into
    the same scan as one whole-stream call — the cross-call analogue of its
    cross-block VMEM carry."""
    import sys
    import repro.kernels.cmetric_fold  # noqa: F401 (shadowed by the fn)
    fk = sys.modules["repro.kernels.cmetric_fold"]
    rng = np.random.default_rng(2)
    e, cut = 1500, 700
    deltas = rng.choice([-1, 1], size=e).astype(np.int32)
    deltas = np.abs(deltas) * (np.cumsum(deltas) > -5) * deltas
    t = np.sort(rng.random(e)).astype(np.float32)
    dt = np.concatenate([np.diff(t), [0.0]]).astype(np.float32)
    n_a, g_a, tot_a, idle_a, cnt_a = fk.fold(jnp.asarray(dt),
                                             jnp.asarray(deltas), block=256)
    n1, g1, tot1, idle1, cnt1 = fk.fold(jnp.asarray(dt[:cut]),
                                        jnp.asarray(deltas[:cut]), block=256)
    n2, g2, tot2, idle2, cnt2 = fk.fold(jnp.asarray(dt[cut:]),
                                        jnp.asarray(deltas[cut:]),
                                        (cnt1, tot1, idle1), block=256)
    np.testing.assert_array_equal(
        np.asarray(n_a), np.concatenate([np.asarray(n1), np.asarray(n2)]))
    np.testing.assert_allclose(
        np.asarray(g_a), np.concatenate([np.asarray(g1), np.asarray(g2)]),
        rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(tot_a), float(tot2), rtol=1e-5)
    np.testing.assert_allclose(float(idle_a), float(idle2), rtol=1e-5,
                               atol=1e-7)
    assert float(cnt_a) == float(cnt2)


def test_carry_cumsum_kernel_matches_numpy():
    rng = np.random.default_rng(3)
    for e in (1, 100, 2048, 5000):
        c = rng.random(e).astype(np.float32)
        i = rng.random(e).astype(np.float32)
        g, i_end = ops.fold_chunk_prefix(0.25, 0.5, c, i, block=256)
        np.testing.assert_allclose(g, 0.25 + np.cumsum(c.astype(np.float64)),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(i_end, 0.5 + i.sum(dtype=np.float64),
                                   rtol=1e-4)
