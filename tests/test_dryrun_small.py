"""Multi-device integration: tiny configs on an 8-placeholder-device mesh.

XLA device count is locked at first jax init, so these run in a
subprocess with XLA_FLAGS set — the same mechanism the production dry-run
uses with 512 devices.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.dryrun import rules_for
from repro.models import init_lm, forward
from repro.optim import adamw
from repro.sharding import api as shapi, params as shparams
from repro.train.step import make_train_step

from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
out = {}
for arch in json.loads(os.environ["ARCHS"]):
    cfg = configs.get_tiny(arch)
    # pad dims so the 4-way model axis divides
    rules = rules_for(arch, "train")
    rules = dataclasses.replace(rules)
    with shapi.use_mesh(mesh, rules):
        params = init_lm(jax.random.PRNGKey(0), cfg)
        p_sh = shparams.param_shardings(
            jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg)),
            mesh, rules)
        params = jax.device_put(params, p_sh)
        opt = adamw.init(params)
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32)}
        if cfg.enc_layers:
            batch["frontend"] = jnp.zeros((8, 8, cfg.frontend_dim))
        elif cfg.frontend_dim:
            batch["frontend"] = jnp.zeros((8, cfg.num_prefix,
                                           cfg.frontend_dim))
        bsh = {k: NamedSharding(mesh, P("data") if v.ndim == 2 or True else P())
               for k, v in batch.items()}
        step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(lr=1e-3)),
                       donate_argnums=(0, 1))
        p2, o2, m, _ = step(params, opt, batch, None)
        loss1 = float(m["loss"])
        p3, o3, m2, _ = step(p2, o2, batch, None)
        out[arch] = {"loss0": loss1, "loss1": float(m2["loss"]),
                     "finite": bool(jnp.isfinite(m2["loss"]))}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.parametrize("archs", [
    ["deepseek-7b", "gemma3-1b", "rwkv6-1.6b"],
    ["recurrentgemma-2b", "grok-1-314b", "arctic-480b"],
    ["qwen3-32b", "seamless-m4t-large-v2", "internvl2-2b", "qwen1.5-4b"],
])
def test_sharded_train_step_8dev(archs):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               ARCHS=json.dumps(archs))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    for arch, res in out.items():
        assert res["finite"], (arch, res)
        # two steps on the same batch: loss must drop
        assert res["loss1"] < res["loss0"], (arch, res)


GPIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.pipeline.gpipe import gpipe

from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("stage",))
def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])
key = jax.random.PRNGKey(0)
stacked = {"w": jax.random.normal(key, (4, 16, 16)) * 0.5}
f = gpipe(stage_fn, mesh, n_stages=4, n_micro=6)
x = jax.random.normal(key, (6, 8, 16))
y = f(stacked, x)
# reference: sequential application of the 4 stages
ref = x
for s in range(4):
    ref = stage_fn({"w": stacked["w"][s]}, ref)
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("GPIPE OK")
"""


def test_gpipe_4stage_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", GPIPE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "GPIPE OK" in r.stdout
