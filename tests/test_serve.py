"""Serving: prefill -> decode continuation equals full-sequence forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_decode_state, init_lm)
from repro.serve.engine import make_prefill_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma3-1b"])
def test_prefill_then_decode_continues(arch):
    """Prefill the first T tokens by teacher-forced decode, then greedy
    decode; the logits at position T must match the full forward at T."""
    cfg = configs.get_tiny(arch)
    cfg = dataclasses.replace(cfg, compute_dtype=jnp.float32)
    params = init_lm(KEY, cfg)
    B, T = 2, 6
    tokens = jax.random.randint(KEY, (B, T + 1), 0, cfg.vocab_size)
    state = init_decode_state(cfg, B, 16)
    for t in range(T):
        lg, state = decode_step(params, tokens[:, t],
                                jnp.full((B,), t, jnp.int32), state, cfg)
    lg_T, _ = decode_step(params, tokens[:, T],
                          jnp.full((B,), T, jnp.int32), state, cfg)
    full, _ = forward(params, {"tokens": tokens}, cfg)
    np.testing.assert_allclose(np.asarray(lg_T), np.asarray(full[:, T]),
                               rtol=1e-4, atol=1e-4)


def test_prefill_step_last_token_logits():
    cfg = configs.get_tiny("qwen3-32b")
    cfg = dataclasses.replace(cfg, compute_dtype=jnp.float32)
    params = init_lm(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)}
    prefill = make_prefill_step(cfg)
    last = prefill(params, batch)
    full, _ = forward(params, batch, cfg)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)
