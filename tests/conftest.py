import os
import sys

# Make `import repro` work regardless of PYTHONPATH.
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
