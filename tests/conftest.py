import os
import sys

import pytest

# Make `import repro` work regardless of PYTHONPATH.
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


# ---------------------------------------------------------------------------
# hypothesis shim: property tests skip cleanly when hypothesis is absent
# (pip install -r requirements-dev.txt to enable them) while plain tests in
# the same module keep running.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                            # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    def _skip_decorator(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="needs hypothesis (pip install -r "
                       "requirements-dev.txt)")(fn)
        return deco

    given = settings = _skip_decorator
    st = _StrategyStub()


# ---------------------------------------------------------------------------
# Lock-order watchdog: a runtime sanitizer mirroring repro.lint's static
# lock-order rule.  Every Lock/RLock created while the suite runs is
# proxied; acquisition order between lock creation sites is recorded, and
# the session fails if the observed order graph ever contains a cycle (a
# latent ABBA deadlock that happened not to interleave).  Opt out with
# GAPP_LOCK_WATCHDOG=0 (e.g. when profiling the suite itself).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session", autouse=True)
def lock_order_watchdog():
    if os.environ.get("GAPP_LOCK_WATCHDOG", "1") == "0":
        yield None
        return
    from repro.lint.watchdog import LockWatchdog
    wd = LockWatchdog()
    wd.install()
    try:
        yield wd
    finally:
        wd.uninstall()
        cycles = wd.cycles()
        assert not cycles, (
            "lock-order watchdog observed a cyclic acquisition order "
            "(latent ABBA deadlock):\n" + "\n".join(cycles))
