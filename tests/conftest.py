import os
import sys

import pytest

# Make `import repro` work regardless of PYTHONPATH.
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


# ---------------------------------------------------------------------------
# hypothesis shim: property tests skip cleanly when hypothesis is absent
# (pip install -r requirements-dev.txt to enable them) while plain tests in
# the same module keep running.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                            # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    def _skip_decorator(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="needs hypothesis (pip install -r "
                       "requirements-dev.txt)")(fn)
        return deco

    given = settings = _skip_decorator
    st = _StrategyStub()
