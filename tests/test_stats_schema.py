"""The stats() schemas are a public contract.

``/metrics`` names derive mechanically from the stats dicts
(``flatten_stats``), and the ``session.stats()`` docstring documents
every counter — so these key sets are pinned: removing or renaming one
is a breaking change this test catches; NEW keys are additive and only
require updating the pinned set (and the docstring, which this test also
enforces for the session).
"""
import time

from repro.core import ProfileSession
from repro.fleet import (IngestServer, ProfilerService, RemoteSink,
                         attach_remote)
from repro.obs.prom import flatten_stats
from tests.test_tracer import FakeClock

SESSION_LIVE_KEYS = {
    "mode", "events_folded", "events_pending", "ring_dropped",
    "tolerance_dropped", "store_rows", "store_resident_rows",
    "resident_bytes", "samples", "watch_errors",
}
SESSION_LIVE_SAMPLES_KEYS = {"ticks", "hits", "stored", "dropped"}
SESSION_OFFLINE_KEYS = {
    "mode", "events_folded", "sanitize_dropped", "slices",
    "critical_rows", "done", "watch_errors",
}
FLEET_SOURCE_KEYS = {
    "hosts", "rows_in", "chunks_in", "buffered_rows", "clock_clamped",
    "shed_chunks", "shed_rows", "idle_hosts", "accepting",
}
INGEST_SERVER_KEYS = {
    "address", "connections", "open_connections", "hosts",
    "stale_chunks", "duplicate_chunks", "lost_chunks", "bad_rows",
    "proto_errors", "backfilled_chunks", "backfilled_rows",
    "deadline_closed", "idle_released", "shed_chunks", "shed_rows",
    "journal_errors", "heartbeats", "fleet_dir",
} | FLEET_SOURCE_KEYS
REMOTE_SINK_KEYS = {
    "host_id", "rows_sent", "chunks_sent", "dropped_chunks", "pending",
    "reconnects", "send_errors", "failed", "codec", "replayed_chunks",
    "replayed_rows", "heartbeats_sent", "journal_errors",
    "server_wire_version", "wire_bytes", "raw_bytes", "journal",
}
SERVICE_KEYS = {
    "address", "requests", "connections", "open_connections",
    "http_errors", "stream_clients", "snapshot_count",
    "snapshot_seconds_sum", "snapshot_seconds_last", "window_folds",
    "window_fold_seconds_sum", "whatif_folds", "whatif_fold_seconds_sum",
    "max_window_s", "retention_pruned_blocks", "retention_errors",
}


def test_session_live_stats_schema():
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk)
    w = s.register_worker("w")
    s.begin(w, "t")
    clk.advance(100)
    s.end(w)
    st = s.stats()
    assert set(st) == SESSION_LIVE_KEYS
    assert set(st["samples"]) == SESSION_LIVE_SAMPLES_KEYS
    s.result()


def test_session_live_stats_with_sinks_key(tmp_path):
    server = IngestServer()
    server.start()
    fleet = ProfileSession(server.source, n_min=1.0)
    fleet.start()
    try:
        clk = FakeClock()
        s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
        w = s.register_worker("w")
        sink = attach_remote(s, server.address, host_id="h")
        s.begin(w, "t")
        clk.advance(100)
        s.end(w)
        st = s.stats()
        assert set(st) == SESSION_LIVE_KEYS | {"sinks"}
        assert set(st["sinks"][0]) == REMOTE_SINK_KEYS
        s.result()
        sink.close()
    finally:
        fleet.stop()
        server.close()


def test_session_offline_and_fleet_source_schema():
    server = IngestServer()
    server.start()
    sess = ProfileSession(server.source, n_min=1.0)
    try:
        st = sess.stats()
        assert set(st) == SESSION_OFFLINE_KEYS | {"source"}
        assert set(st["source"]) == FLEET_SOURCE_KEYS
        assert set(server.stats()) == INGEST_SERVER_KEYS
    finally:
        sess.stop()
        server.close()


def test_service_stats_schema():
    s = ProfileSession(n_min=1.0, clock=FakeClock())
    svc = ProfilerService(s)
    try:
        assert set(svc.stats()) == SERVICE_KEYS
    finally:
        svc.close()
        s.result()


def test_session_stats_docstring_documents_every_key():
    doc = ProfileSession.stats.__doc__
    for key in (SESSION_LIVE_KEYS | SESSION_OFFLINE_KEYS | {"sinks"}
                | FLEET_SOURCE_KEYS):
        assert f"``{key}``" in doc, f"stats() docstring missing {key!r}"


def test_metric_names_derived_from_schema_are_stable():
    """The gauge names a dashboard would reference: prefix + key, with
    nested dicts joined — pin the derivation for the session schema."""
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk)
    names = {n for n, _, _ in flatten_stats("gapp_session", s.stats())}
    assert names == {
        "gapp_session_events_folded", "gapp_session_events_pending",
        "gapp_session_ring_dropped", "gapp_session_tolerance_dropped",
        "gapp_session_store_rows", "gapp_session_store_resident_rows",
        "gapp_session_resident_bytes", "gapp_session_samples_ticks",
        "gapp_session_samples_hits", "gapp_session_samples_stored",
        "gapp_session_samples_dropped", "gapp_session_watch_errors",
    }   # "mode" is a string -> identity, not telemetry
    s.result()
