"""SpillStore.open_readonly edge cases: truncated tail blocks, the
zero-flushed-byte watermark, and readers opened mid-write."""
import os

import numpy as np
import pytest

from repro.core import SpillStore, synthetic_log


def _fill(path, log, chunk_events=64):
    st = SpillStore(str(path), chunk_events=chunk_events)
    st.append_columns(log.times, log.workers, log.deltas, log.tags,
                      log.stacks)
    st.close()
    return st


def test_truncated_tail_block_ignored(tmp_path):
    """A capture cut mid-block (power loss, copy-in-flight) must replay
    every complete block and silently drop the torn tail."""
    log = synthetic_log(np.random.default_rng(0), 2, 96)   # 384 rows
    path = tmp_path / "t.spill"
    _fill(path, log, chunk_events=64)                       # 6 full blocks
    size = os.path.getsize(path)
    # chop into the payload of the last block
    with open(path, "r+b") as f:
        f.truncate(size - 40)
    ro = SpillStore.open_readonly(str(path), 64)
    assert ro.rows_on_disk == 5 * 64
    chunks = list(ro.iter_chunks(log.num_workers))
    assert sum(len(c) for c in chunks) == 5 * 64
    back = ro.freeze(log.num_workers)
    np.testing.assert_array_equal(back.times, log.times[:5 * 64])


def test_truncated_inside_header_ignored(tmp_path):
    log = synthetic_log(np.random.default_rng(1), 2, 64)
    path = tmp_path / "h.spill"
    _fill(path, log, chunk_events=64)
    with open(path, "ab") as f:
        f.write(b"\x07\x00\x00")        # 3 bytes of a phantom next header
    ro = SpillStore.open_readonly(str(path), 64)
    assert ro.rows_on_disk == len(log)
    assert len(ro.freeze(log.num_workers)) == len(log)


def test_header_only_tail_with_missing_payload(tmp_path):
    """A complete header whose payload never landed: the row count it
    declares must not be trusted."""
    log = synthetic_log(np.random.default_rng(2), 2, 64)
    path = tmp_path / "p.spill"
    _fill(path, log, chunk_events=64)
    import struct
    with open(path, "ab") as f:
        f.write(struct.pack("<Q", 1 << 20))   # block claims 1M rows, no data
    ro = SpillStore.open_readonly(str(path), 64)
    assert ro.rows_on_disk == len(log)
    assert len(ro.freeze(log.num_workers)) == len(log)
    assert sum(len(c) for c in ro.iter_chunks(log.num_workers)) == len(log)


def test_zero_flushed_bytes_watermark(tmp_path):
    """Nothing flushed yet: a read-only open (missing file, empty file, or
    a writer with only buffered rows) yields an empty stream, not an
    error."""
    missing = SpillStore.open_readonly(str(tmp_path / "nope.spill"))
    assert len(missing) == 0
    assert list(missing.iter_chunks(2)) == []
    assert len(missing.freeze(2)) == 0

    empty = tmp_path / "empty.spill"
    empty.touch()
    ro = SpillStore.open_readonly(str(empty))
    assert ro.rows_on_disk == 0 and list(ro.iter_chunks(2)) == []

    # writer holding everything in RAM: on-disk watermark is still zero
    log = synthetic_log(np.random.default_rng(3), 2, 4)    # 16 rows < chunk
    w = SpillStore(str(tmp_path / "buf.spill"), chunk_events=1024)
    w.append_columns(log.times, log.workers, log.deltas, log.tags,
                     log.stacks)
    assert w.rows_on_disk == 0 and w.resident_rows == 16
    ro2 = SpillStore.open_readonly(str(tmp_path / "buf.spill"))
    assert len(ro2) == 0 and list(ro2.iter_chunks(2)) == []
    w.close()


def test_reader_opened_mid_write_sees_flushed_prefix_only(tmp_path):
    """A reader attaching while the writer is live sees exactly the blocks
    flushed at open time; later flushes appear to *new* readers without
    disturbing the first one."""
    log = synthetic_log(np.random.default_rng(4), 2, 96)   # 384 rows
    path = str(tmp_path / "live.spill")
    w = SpillStore(path, chunk_events=64)
    c1 = log.chunk(0, 192)
    w.append_columns(c1.times, c1.workers, c1.deltas, c1.tags, c1.stacks)
    # 3 blocks on disk; nothing buffered
    ro = SpillStore.open_readonly(path, 64)
    assert ro.rows_on_disk == 192
    first = list(ro.iter_chunks(log.num_workers))
    assert sum(len(c) for c in first) == 192

    c2 = log.chunk(192, 384)
    w.append_columns(c2.times, c2.workers, c2.deltas, c2.tags, c2.stacks)
    w.spill()
    # the early reader's watermark is pinned at its open-time scan
    assert ro.rows_on_disk == 192
    again = list(ro.iter_chunks(log.num_workers))
    assert sum(len(c) for c in again) == 192
    # a fresh reader picks up the new flushed prefix
    ro2 = SpillStore.open_readonly(path, 64)
    assert ro2.rows_on_disk == 384
    np.testing.assert_array_equal(ro2.freeze(log.num_workers).times,
                                  log.times)
    w.close()


def test_readonly_store_rejects_appends(tmp_path):
    log = synthetic_log(np.random.default_rng(5), 2, 8)
    path = tmp_path / "ro.spill"
    _fill(path, log)
    ro = SpillStore.open_readonly(str(path))
    with pytest.raises(ValueError):
        ro.append_columns(log.times, log.workers, log.deltas, log.tags,
                          log.stacks)


# ---------------------------------------------------------------------------
# journal mode: open_append + append_block (block index == seq)
# ---------------------------------------------------------------------------

def _block(log, lo, hi):
    c = log.chunk(lo, hi)
    return (c.times, c.workers, c.deltas, c.tags, c.stacks)


def test_append_block_indexes_and_replay(tmp_path):
    """One append_block == one block, in order: the journal invariant the
    fleet replay builds its seq numbering on."""
    log = synthetic_log(np.random.default_rng(6), 2, 64)   # 256 rows
    path = str(tmp_path / "j.spill")
    j = SpillStore.open_append(path)
    sizes = (10, 1, 37, 100)
    lo = 0
    for i, n in enumerate(sizes):
        assert j.append_block(*_block(log, lo, lo + n)) == i
        lo += n
    assert j.blocks == len(sizes)
    # replay skipping a prefix yields exactly the tail blocks, same shapes
    tail = list(j.iter_block_columns(skip=2))
    assert [len(c[0]) for c in tail] == [37, 100]
    np.testing.assert_array_equal(tail[0][0], log.times[11:48])
    j.close()


def test_open_append_resumes_after_complete_history(tmp_path):
    log = synthetic_log(np.random.default_rng(7), 2, 48)
    path = str(tmp_path / "r.spill")
    j = SpillStore.open_append(path)
    j.append_block(*_block(log, 0, 50))
    j.append_block(*_block(log, 50, 120))
    j.close()
    # a fresh open (producer restart) resumes the block numbering
    j2 = SpillStore.open_append(path)
    assert j2.blocks == 2
    assert j2.append_block(*_block(log, 120, 192)) == 2
    back = j2.freeze(log.num_workers)
    np.testing.assert_array_equal(back.times, log.times)
    j2.close()


def test_open_append_truncates_torn_tail_to_resume_floor(tmp_path):
    """A crash mid-append leaves a torn tail block; reopening the journal
    must cut it back to the last complete block so (a) the resume floor
    (block count) is exact and (b) the next append starts at a clean frame
    instead of corrupting the stream."""
    log = synthetic_log(np.random.default_rng(8), 2, 64)   # 256 rows
    path = str(tmp_path / "torn.spill")
    j = SpillStore.open_append(path)
    for lo in range(0, 256, 64):
        j.append_block(*_block(log, lo, lo + 64))
    j.close()
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 33)              # rip into the last payload
    j2 = SpillStore.open_append(path)
    assert j2.blocks == 3                  # torn tail excluded from floor
    assert os.path.getsize(path) < size    # ...and physically removed
    # re-append the recovered block: the file is whole again
    assert j2.append_block(*_block(log, 192, 256)) == 3
    back = j2.freeze(log.num_workers)
    np.testing.assert_array_equal(back.times, log.times)
    # a replay skipping the acked prefix sees the re-appended tail
    assert [len(c[0]) for c in j2.iter_block_columns(skip=3)] == [64]
    j2.close()
