"""Streaming ProfileSession: snapshot-during-capture == offline oracle,
spill-bounded memory, pluggable sources, exporter registry, live watch,
and the deprecated Gapp/profile_log wrappers."""
import json
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import (ProfileSession, SpillSource, SpillStore,
                        available_exporters, compute_numpy, detect_offline,
                        export, register_exporter, synthetic_log)
from repro.core.exporters import unregister_exporter
from repro.core.tracer import StackRegistry, TagRegistry
from tests.test_tracer import FakeClock


def _ranked(rep):
    return [(rep.path_str(p), p.cmetric, p.slices) for p in rep.paths]


# ---------------------------------------------------------------------------
# acceptance: live snapshot mid-capture, quiesce, result == offline oracle
# ---------------------------------------------------------------------------

def test_live_snapshot_then_result_bit_equal_to_offline_oracle():
    """snapshot() during live multi-threaded capture, then quiesce +
    result(): the final report must be bit-equal (numpy backend) to the
    one-shot detect_offline oracle on the same frozen log."""
    nt, iters = 4, 1500
    s = ProfileSession(n_min=2.0, capacity=1 << 14, drain_interval=0.001)
    wids = [s.register_worker(f"t{i}") for i in range(nt)]
    mid_reports = []

    def hammer(wid):
        h = s.handle(wid)
        for i in range(iters):
            with h.span(("step", "io", "net")[i % 3]):
                pass

    threads = [threading.Thread(target=hammer, args=(w,)) for w in wids]
    with s.running():
        for t in threads:
            t.start()
        # incremental snapshots while producers are mid-flight
        for _ in range(5):
            mid_reports.append(s.snapshot())
            time.sleep(0.002)
        for t in threads:
            t.join()
    rep = s.result()

    # the mid-capture snapshots were real incremental reports
    assert all(r.total_slices <= rep.total_slices for r in mid_reports)
    assert rep.total_slices == nt * iters
    assert s.tracer.ring.dropped == 0

    log = s.freeze()
    log.validate()
    oracle = detect_offline(log, s.tags, s.stacks, 2.0,
                            samples=s.probe.buffer
                            if len(s.probe.buffer) else None,
                            worker_names=s.tracer.worker_names())
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert rep.total_critical == oracle.total_critical
    assert rep.total_slices == oracle.total_slices
    assert rep.idle_time == oracle.idle_time
    assert rep.total_time == oracle.total_time
    assert _ranked(rep) == _ranked(oracle)
    # per-slice agreement, bit-for-bit
    np.testing.assert_array_equal(rep.critical_table.cm,
                                  oracle.critical_table.cm)
    np.testing.assert_array_equal(rep.critical_table.threads_av,
                                  oracle.critical_table.threads_av)


# ---------------------------------------------------------------------------
# acceptance: disk spill bounds resident event memory at O(chunk_events)
# ---------------------------------------------------------------------------

def test_spill_session_bounds_resident_memory(tmp_path):
    """A spill-enabled session streams >=10x chunk_events events while the
    store's resident buffer never exceeds one chunk; the spilled file
    freezes back to the exact log and the final report matches it."""
    chunk = 512
    clk = FakeClock()
    s = ProfileSession(n_min=1.5, clock=clk, capacity=1024,
                       spill_path=str(tmp_path / "events.spill"),
                       chunk_events=chunk)
    w = [s.register_worker(f"w{i}") for i in range(2)]
    pairs = 10 * chunk  # 4 events per iteration => 40x chunk_events total
    for _ in range(pairs):
        s.begin(w[0], "a")
        clk.advance(1_000)
        s.begin(w[1], "b")
        clk.advance(1_000)
        s.end(w[1])
        clk.advance(500)
        s.end(w[0])
        clk.advance(500)
    rep = s.result()
    store = s.tracer.store
    assert isinstance(store, SpillStore)
    assert len(store) == 4 * pairs >= 10 * chunk
    # the memory bound: the RAM buffer never held more than one chunk
    assert store.max_resident_rows <= chunk
    assert store.rows_on_disk == 4 * pairs
    assert store.resident_nbytes < 64 * chunk   # 21B/row buffer, no growth
    # read-back equals what an unbounded store would have accumulated
    log = s.freeze()
    log.validate()
    assert len(log) == 4 * pairs
    res = compute_numpy(log)
    np.testing.assert_array_equal(res.per_worker, rep.per_worker)
    assert rep.total_slices == 2 * pairs
    # streaming re-analysis of the spilled file, block by block, agrees too
    replay = ProfileSession(
        SpillSource(store, log.num_workers, tags=s.tags, stacks=s.stacks),
        n_min=1.5)
    rep2 = replay.result()
    np.testing.assert_array_equal(rep2.per_worker, rep.per_worker)
    assert rep2.total_critical == rep.total_critical


# ---------------------------------------------------------------------------
# offline sources
# ---------------------------------------------------------------------------

def test_offline_session_matches_detect_offline():
    rng = np.random.default_rng(7)
    log = synthetic_log(rng, 6, 150)
    oracle = detect_offline(log, TagRegistry(), StackRegistry(), n_min=3.0,
                            sample_dt_ns=500_000)
    for chunk_events in (None, 101, 4096):
        s = ProfileSession.offline(log, n_min=3.0,
                                   chunk_events=chunk_events,
                                   sample_dt_ns=500_000)
        rep = s.result()
        np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
        assert rep.total_slices == oracle.total_slices
        assert rep.total_critical == oracle.total_critical
        assert _ranked(rep) == _ranked(oracle)


def test_offline_session_background_worker():
    """start() folds chunks on the worker thread; result() joins it."""
    rng = np.random.default_rng(3)
    log = synthetic_log(rng, 4, 400)
    oracle = detect_offline(log, TagRegistry(), StackRegistry(), n_min=2.0)
    s = ProfileSession.offline(log, n_min=2.0, chunk_events=64)
    s.start()
    # incremental snapshots while the worker folds
    partial = s.snapshot()
    assert partial.total_slices <= oracle.total_slices
    rep = s.result()
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert rep.total_slices == oracle.total_slices
    assert s.stats()["done"]


def test_offline_session_sanitizes_dirty_streams():
    rng = np.random.default_rng(11)
    log = synthetic_log(rng, 4, 60)
    # corrupt: duplicate ACTIVATEs (spurious wakeups)
    dirty_idx = np.where(log.deltas == 1)[0][::3]
    times = np.insert(log.times, dirty_idx, log.times[dirty_idx])
    workers = np.insert(log.workers, dirty_idx, log.workers[dirty_idx])
    deltas = np.insert(log.deltas, dirty_idx, 1)
    tags = np.insert(log.tags, dirty_idx, -1)
    stacks = np.insert(log.stacks, dirty_idx, -1)
    from repro.core import EventLog
    dirty = EventLog(times, workers, deltas, tags, stacks, log.num_workers)
    oracle = detect_offline(dirty, TagRegistry(), StackRegistry(), n_min=2.0)
    s = ProfileSession.offline(dirty, n_min=2.0, chunk_events=97)
    rep = s.result()
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert s.stats()["sanitize_dropped"] == len(dirty_idx)


def test_offline_session_has_no_live_api():
    s = ProfileSession.offline(synthetic_log(np.random.default_rng(0), 2, 5),
                               n_min=1.0)
    with pytest.raises(RuntimeError):
        s.register_worker("x")
    with pytest.raises(RuntimeError):
        s.begin(0, "t")


# ---------------------------------------------------------------------------
# exporter registry
# ---------------------------------------------------------------------------

def _tiny_live_session():
    clk = FakeClock()
    s = ProfileSession(n_min=1.9, clock=clk)
    w = [s.register_worker(f"w{i}") for i in range(2)]
    for _ in range(4):
        s.begin(w[0], "par")
        s.begin(w[1], "par")
        clk.advance(1_000_000)
        s.end(w[0])
        s.end(w[1])
        s.begin(w[0], "serial")
        clk.advance(2_000_000)
        s.end(w[0])
    return s


def test_exporter_registry_builtins():
    assert {"text", "json", "chrome", "callback", "watch"} <= \
        set(available_exporters())
    s = _tiny_live_session()
    text = s.export("text", max_paths=2)
    assert "GAPP bottleneck profile" in text and "serial" in text
    d = json.loads(s.export("json"))
    assert d["schema_version"] >= 2
    trace = json.loads(s.export("chrome"))
    assert any(e.get("name") == "serial" for e in trace["traceEvents"])
    got = []
    s.export("callback", callback=got.append)
    assert len(got) == 1 and got[0].total_slices == 12
    with pytest.raises(KeyError):
        s.export("no-such-format")


def test_exporter_chrome_needs_log_or_session():
    s = _tiny_live_session()
    rep = s.snapshot()
    with pytest.raises(ValueError):
        export(rep, "chrome")
    out = export(rep, "chrome", session=s)
    assert json.loads(out)["traceEvents"]


def test_register_custom_exporter():
    def _csv(rep, *, session=None, **kw):
        return "\n".join(f"{rep.path_str(p)},{p.cmetric}" for p in rep.paths)
    register_exporter("csv", _csv, capabilities={"machine"})
    try:
        s = _tiny_live_session()
        out = s.export("csv")
        assert out.splitlines()[0].startswith("serial,")
    finally:
        unregister_exporter("csv")


def test_chrome_export_to_path(tmp_path):
    s = _tiny_live_session()
    p = tmp_path / "trace.json"
    s.export("chrome", path=str(p))
    assert json.loads(p.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# live watch
# ---------------------------------------------------------------------------

def test_watch_pushes_live_and_final_reports():
    s = ProfileSession(n_min=1.0, drain_interval=0.002)
    w = s.register_worker("w")
    seen = []
    unsubscribe = s.watch(seen.append, every=0.0)
    with s.running():
        for _ in range(20):
            with s.span(w, "work"):
                time.sleep(0.001)
    assert seen, "no live updates during the run"
    n_live = len(seen)
    s.close()                    # final push fires even after unsubscribe #2
    assert len(seen) == n_live + 1
    final = seen[-1]
    assert final.total_slices == 20
    unsubscribe()
    assert s.watch_errors == []


def test_watch_via_exporter_and_errors_recorded():
    s = ProfileSession(n_min=1.0)
    w = s.register_worker("w")
    calls = []
    unsubscribe = s.export("watch", callback=calls.append, every=0.0)
    assert callable(unsubscribe)

    def boom(rep):
        raise RuntimeError("watcher bug")
    s.watch(boom, every=0.0)
    with s.span(w, "x"):
        pass
    s.close()                    # fires both watchers; boom must not raise
    assert calls and len(s.watch_errors) == 1


# ---------------------------------------------------------------------------
# deprecated wrappers
# ---------------------------------------------------------------------------

def test_gapp_wrapper_delegates_to_session():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        from repro.core import Gapp
        g = Gapp(n_min=1.9, clock=FakeClock())
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)
    assert isinstance(g.session, ProfileSession)
    clk = g.tracer.clock
    a = g.register_worker("a")
    g.register_worker("b")
    g.begin(a, "solo")
    clk.advance(1_000_000)
    g.end(a)
    rep = g.report()
    assert rep.total_critical == 1
    assert g.session.snapshot().total_critical == 1


def test_gapp_begin_callsite_resolved_once_and_loc_override():
    """Satellite: begin() no longer walks sys._getframe per call — the
    callsite is interned once per distinct tag and points at the *user*
    module (not the facade), and loc= overrides it explicitly."""
    from repro.core import Gapp
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        g = Gapp(n_min=1.0, clock=FakeClock())
    w = g.register_worker("w")
    g.begin(w, "hot_tag")
    g.end(w)
    tid = g.tracer.tags._ids["hot_tag"]
    loc = g.tracer.tags.locations[tid]
    assert loc.split(":")[0].endswith("test_session"), loc
    # explicit location: no frame walk at all
    g.begin(w, "explicit_tag", loc="my_module:42")
    g.end(w)
    tid2 = g.tracer.tags._ids["explicit_tag"]
    assert g.tracer.tags.locations[tid2] == "my_module:42"
    # repeated begins of a known tag never re-intern (location is stable)
    g.begin(w, "hot_tag")
    g.end(w)
    assert g.tracer.tags.locations[tid] == loc


def test_profile_log_wrapper_matches_detect_offline():
    rng = np.random.default_rng(5)
    log = synthetic_log(rng, 4, 80)
    oracle = detect_offline(log, TagRegistry(), StackRegistry(), n_min=2.0,
                            sample_dt_ns=1_000_000)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import profile_log
        rep = profile_log(log, TagRegistry(), StackRegistry(), n_min=2.0,
                          sample_dt_ns=1_000_000)
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert _ranked(rep) == _ranked(oracle)


# ---------------------------------------------------------------------------
# spill store unit behaviour
# ---------------------------------------------------------------------------

def test_spill_store_roundtrip_and_chunking(tmp_path):
    rng = np.random.default_rng(2)
    log = synthetic_log(rng, 3, 300)
    st = SpillStore(str(tmp_path / "s.bin"), chunk_events=128)
    # append in odd-sized pieces; blocks must still be exactly chunk-sized
    for lo in range(0, len(log), 77):
        c = log.chunk(lo, lo + 77)
        st.append_columns(c.times, c.workers, c.deltas, c.tags, c.stacks)
    assert len(st) == len(log)
    assert st.max_resident_rows <= 128
    back = st.freeze(log.num_workers)
    for col in ("times", "workers", "deltas", "tags", "stacks"):
        np.testing.assert_array_equal(getattr(back, col), getattr(log, col))
    chunks = list(st.iter_chunks(log.num_workers))
    assert sum(len(c) for c in chunks) == len(log)
    assert all(len(c) <= 128 for c in chunks)
    st.close()
    with pytest.raises(ValueError):
        st.append_columns(log.times[:1], log.workers[:1], log.deltas[:1],
                          log.tags[:1], log.stacks[:1])


def test_spill_store_owns_its_file_and_readonly_replays(tmp_path):
    """Regression: a writer store at a reused path must not leak the
    previous run's events into freeze(); replay opens read-only (no
    truncation) — including SpillSource given a bare path."""
    path = str(tmp_path / "reuse.spill")
    log = synthetic_log(np.random.default_rng(1), 2, 30)
    st1 = SpillStore(path, chunk_events=16)
    st1.append_columns(log.times, log.workers, log.deltas, log.tags,
                       log.stacks)
    st1.close()
    # second capture at the same path: first run's rows must be gone
    st2 = SpillStore(path, chunk_events=16)
    c = log.chunk(0, 8)
    st2.append_columns(c.times, c.workers, c.deltas, c.tags, c.stacks)
    assert len(st2) == 8
    assert len(st2.freeze(log.num_workers)) == 8
    st2.close()
    # read-only open indexes the existing file without touching it
    ro = SpillStore.open_readonly(path)
    assert ro.rows_on_disk == 8
    with pytest.raises(ValueError):
        ro.append_columns(c.times, c.workers, c.deltas, c.tags, c.stacks)
    np.testing.assert_array_equal(ro.freeze(log.num_workers).times, c.times)
    # SpillSource(path) replays, and the file survives (not truncated)
    src = SpillSource(path, log.num_workers)
    assert sum(len(ch) for ch in src.chunks()) == 8
    assert SpillStore.open_readonly(path).rows_on_disk == 8


# ---------------------------------------------------------------------------
# per-shard decode budget (max_rows_per_sync)
# ---------------------------------------------------------------------------

def test_max_rows_per_sync_bounds_snapshot_decode_and_final_is_exact():
    """With a decode budget, a mid-capture snapshot folds at most one
    budget's worth per shard (bounded latency, lagging report); close()
    consumes the backlog so the final report is complete and bit-equal to
    the offline oracle."""
    clk = FakeClock()
    budget = 64
    s = ProfileSession(n_min=1.5, clock=clk, capacity=1 << 15,
                       max_rows_per_sync=budget)
    w = [s.register_worker(f"w{i}") for i in range(2)]
    pairs = 2000
    for _ in range(pairs):
        s.begin(w[0], "a")
        clk.advance(1000)
        s.begin(w[1], "b")
        clk.advance(1000)
        s.end(w[1])
        clk.advance(500)
        s.end(w[0])
        clk.advance(500)
    total = 4 * pairs
    assert s.tracer.ring.pending() == total
    mid = s.snapshot()                  # one budgeted flush only
    folded = total - s.tracer.ring.pending()
    assert 0 < folded <= budget * 2     # <= budget per shard
    assert mid.total_slices <= folded
    rep = s.result()                    # close(): full sync, then seal
    assert s.tracer.ring.pending() == 0
    assert rep.total_slices == 2 * pairs
    log = s.freeze()
    log.validate()
    res = compute_numpy(log)
    np.testing.assert_array_equal(res.per_worker, rep.per_worker)
    assert len(log) == total


def test_max_rows_per_sync_skewed_shards_times_not_clamped():
    """A sparse worker next to a dense one: capped drains must not merge
    the sparse shard's far future with the dense shard's past — the time
    frontier trims each take, so the accumulated log keeps the exact
    original timestamps (no monotonic-clamp distortion)."""
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, capacity=1 << 15,
                       max_rows_per_sync=64, autoflush=False)
    dense = s.register_worker("dense")
    sparse = s.register_worker("sparse")
    expected = []
    for i in range(800):
        if i % 160 == 0:            # sparse worker fires rarely
            s.begin(sparse, "s")
            expected.append((clk.t, sparse, 1))
            clk.advance(50)
            s.end(sparse)
            expected.append((clk.t, sparse, -1))
            clk.advance(50)
        s.begin(dense, "d")
        expected.append((clk.t, dense, 1))
        clk.advance(100)
        s.end(dense)
        expected.append((clk.t, dense, -1))
        clk.advance(100)
    rep = s.result()
    log = s.freeze()
    log.validate()
    assert len(log) == len(expected)
    exp = sorted(range(len(expected)), key=lambda i: expected[i][0])
    np.testing.assert_array_equal(log.times,
                                  [expected[i][0] for i in exp])
    np.testing.assert_array_equal(log.workers,
                                  [expected[i][1] for i in exp])
    res = compute_numpy(log)
    np.testing.assert_array_equal(res.per_worker, rep.per_worker)


def test_max_rows_per_sync_full_sync_still_complete():
    """Tracer.sync() stays exhaustive under a budget: it bites the backlog
    off in budget-sized flushes instead of one unbounded decode."""
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, max_rows_per_sync=32)
    w = s.register_worker("w")
    for _ in range(500):
        s.begin(w, "x")
        clk.advance(100)
        s.end(w)
        clk.advance(100)
    s.tracer.sync()
    assert s.tracer.ring.pending() == 0
    assert s.stats()["events_folded"] == 1000


# ---------------------------------------------------------------------------
# deprecated-wrapper gap: chunk_events reaches the offline session
# ---------------------------------------------------------------------------

def test_profile_log_forwards_chunk_events(monkeypatch):
    """profile_log(chunk_events=...) must stream the replay through
    bounded chunks — pin the forwarding and the result equivalence."""
    from repro.core import profile_log
    from repro.core.session import LogSource
    rng = np.random.default_rng(13)
    log = synthetic_log(rng, 4, 120)
    seen = []
    orig = LogSource.chunks

    def spy(self):
        for part in orig(self):
            seen.append(len(part))
            yield part
    monkeypatch.setattr(LogSource, "chunks", spy)
    oracle = detect_offline(log, TagRegistry(), StackRegistry(), n_min=2.0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        rep = profile_log(log, TagRegistry(), StackRegistry(), n_min=2.0,
                          sample_dt_ns=None, chunk_events=77)
    assert seen and max(seen) <= 77 and sum(seen) == len(log)
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert _ranked(rep) == _ranked(oracle)


def test_dump_chrome_trace_accepts_sessions(tmp_path):
    from repro.core import dump_chrome_trace
    s = _tiny_live_session()
    p = tmp_path / "live.json"
    dump_chrome_trace(s, str(p))
    assert json.loads(p.read_text())["traceEvents"]
    off = ProfileSession.offline(s.freeze(), s.tags, s.stacks, n_min=1.9)
    off.result()
    p2 = tmp_path / "off.json"
    dump_chrome_trace(off, str(p2))
    assert json.loads(p2.read_text()) == json.loads(p.read_text())
