"""Durable fleet ingest: producer journals + ack_seq reconnect replay,
producer kill/restart resume, server fleet_dir persistence with
restart backfill, and offline from_fleet_dir equality.

The acceptance property throughout: after any combination of producer or
server restarts, the final fleet report is bit-equal (numpy) to
``detect_offline`` over the merged journals, with zero ``lost_chunks``.
"""
import json
import os
import time

import numpy as np

from repro.core import ProfileSession, detect_offline
from repro.fleet import FleetSource, IngestServer, RemoteSink, attach_remote
from tests.test_tracer import FakeClock


def _ranked(rep):
    return [(rep.path_str(p), p.cmetric, p.slices) for p in rep.paths]


def _wait(cond, timeout=5.0):
    deadline = time.time() + timeout
    while not cond() and time.time() < deadline:
        time.sleep(0.01)
    assert cond()


def _stream_spans(s, w, clk, n, tag="x"):
    for _ in range(n):
        s.begin(w, tag)
        clk.advance(1000)
        s.end(w)
        clk.advance(500)


def _assert_fleet_equals_journals(rep, fleet_dir, n_min=1.0):
    """The live fleet report vs detect_offline on the merged durable
    per-host stores."""
    src = FleetSource.from_fleet_dir(fleet_dir)
    merged = src.full_log()
    oracle = detect_offline(merged, src.tags, src.stacks, n_min=n_min)
    np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
    assert rep.total_slices == oracle.total_slices
    assert rep.total_critical == oracle.total_critical
    assert rep.idle_time == oracle.idle_time
    assert rep.total_time == oracle.total_time
    assert _ranked(rep) == _ranked(oracle)
    return merged


# ---------------------------------------------------------------------------
# acceptance: producer kill + restart mid-capture, zero lost chunks
# ---------------------------------------------------------------------------

def test_producer_restart_resumes_capture_no_loss(tmp_path):
    """Phase 1 streams and 'dies' (graceful transport close, no process
    state survives); phase 2 opens a FRESH session on the same journal:
    the instance nonce, seq numbering and tag-id space all resume, so the
    server folds both incarnations as one gapless capture."""
    journal = str(tmp_path / "hostA.journal")
    fleet_dir = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=fleet_dir)
    server.start()
    fleet_sess = ProfileSession(server.source, n_min=1.0)
    fleet_sess.start()
    try:
        instances = []
        for phase in range(2):
            clk = FakeClock()
            clk.t = phase * 10_000_000      # restart: clock moves forward
            s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
            w = s.register_worker("w")
            sink = attach_remote(s, server.address, host_id="hostA",
                                 clock_offset_ns=0, journal=journal)
            instances.append(sink.instance)
            # restart seeds the tag registry from the journal meta, so
            # phase 2's "warm"/"x" ids extend phase 1's space
            _stream_spans(s, w, clk, 50, tag="x")
            _stream_spans(s, w, clk, 20, tag=f"warm{phase}")
            s.result()
            sink.close()
            assert not sink.failed and sink.dropped_chunks == 0
        # the resumed sink repeated the capture nonce — that is WHY the
        # server kept its dedup floor instead of re-folding history
        assert instances[0] == instances[1]
        assert server.wait_idle(10), server.stats()
        rep = fleet_sess.result()
        st = server.stats()
    finally:
        fleet_sess.stop()
        server.close()

    assert st["hosts"] == 1
    assert st["lost_chunks"] == 0
    assert st["duplicate_chunks"] == 0
    assert st["rows_in"] == 280                 # (50+20)*2 rows per phase
    assert rep.total_slices == 140
    merged = _assert_fleet_equals_journals(rep, fleet_dir)
    # the producer journal carries the whole capture too (both phases)
    from repro.core import SpillStore
    back = SpillStore.open_readonly(journal).freeze(1)
    assert len(back) == 280
    np.testing.assert_array_equal(np.sort(back.times), merged.times)
    # tag names resolved across the restart (no id collisions)
    assert {"x", "warm0", "warm1"} <= set(rep.tag_names)


def test_server_restart_ack0_triggers_full_journal_replay(tmp_path):
    """The server loses ALL state (no fleet_dir): its WELCOME ack_seq
    falls back to 0 and the journaling producer replays its entire
    history — seq gaps become recovered history, not lost_chunks."""
    journal = str(tmp_path / "h.journal")
    server = IngestServer()
    server.start()
    addr = server.address
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, addr, host_id="h", clock_offset_ns=0,
                         journal=journal, reconnect_delay=0.01)
    try:
        _stream_spans(s, w, clk, 30)
        s.snapshot()                    # sync the shards -> sink
        assert sink.flush(5.0)
        _wait(lambda: server.source.stats()["rows_in"] == 60)
        # hard server loss: every byte of ingest state vanishes
        server.close()
        server = IngestServer(addr)     # same port, empty state
        server.start()
        _stream_spans(s, w, clk, 10)
        s.result()
        sink.close()
        assert not sink.failed, sink.last_error
        assert server.wait_idle(10), server.stats()
        st = server.stats()
        # the new server folded the WHOLE capture: replayed history + new
        assert st["rows_in"] == 80, st
        assert st["lost_chunks"] == 0, st
        assert st["duplicate_chunks"] == 0, st
        assert sink.replayed_chunks > 0
        rep = ProfileSession(server.source, n_min=1.0).result()
        assert rep.total_slices == 40
    finally:
        server.close()


def test_fleet_dir_server_restart_restores_floor_and_backfills(tmp_path):
    """A fleet_dir server restart: the reconnecting host's meta+journal
    restore the dedup floor (ack_seq survives, so nothing re-folds) and
    the journaled history is backfilled into the fresh merge — the host
    reconnects WITH history."""
    fleet_dir = str(tmp_path / "fleet")
    journal = str(tmp_path / "h.journal")
    server = IngestServer(fleet_dir=fleet_dir)
    server.start()
    addr = server.address
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, addr, host_id="h", clock_offset_ns=0,
                         journal=journal, reconnect_delay=0.01)
    try:
        _stream_spans(s, w, clk, 40, tag="phase1")
        s.snapshot()                    # sync the shards -> sink
        assert sink.flush(5.0)
        _wait(lambda: server.stats()["rows_in"] == 80)
        server.close()

        server = IngestServer(addr, fleet_dir=fleet_dir)
        server.start()
        fleet_sess = ProfileSession(server.source, n_min=1.0)
        fleet_sess.start()
        _stream_spans(s, w, clk, 15, tag="phase2")
        s.result()
        sink.close()
        assert not sink.failed, sink.last_error
        assert server.wait_idle(10), server.stats()
        rep = fleet_sess.result()
        st = server.stats()
        # floor restored from the meta: the server deduped nothing — the
        # phase-1 history came from the backfill, not a producer replay
        assert st["duplicate_chunks"] == 0 and st["lost_chunks"] == 0, st
        assert st["backfilled_rows"] == 80, st
        assert rep.total_slices == 55
        assert {"phase1", "phase2"} <= set(rep.tag_names)
        _assert_fleet_equals_journals(rep, fleet_dir)
    finally:
        server.close()


# ---------------------------------------------------------------------------
# fleet_dir offline replay + meta contents
# ---------------------------------------------------------------------------

def test_from_fleet_dir_matches_live_two_hosts(tmp_path):
    fleet_dir = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=fleet_dir)
    server.start()
    fleet_sess = ProfileSession(server.source, n_min=2.0)
    fleet_sess.start()
    try:
        prods = []
        for hi in range(2):
            clk = FakeClock()
            clk.t = hi * 137
            s = ProfileSession(n_min=2.0, clock=clk, drain_interval=0.001)
            wids = [s.register_worker(f"t{i}") for i in range(2)]
            sink = attach_remote(s, server.address, host_id=f"host{hi}",
                                 clock_offset_ns=0)
            prods.append((s, wids, clk, sink))
            _wait(lambda hi=hi: server.stats()["hosts"] == hi + 1)
        for (s, wids, clk, sink) in prods:
            with s.running():
                for _ in range(100):
                    s.begin(wids[0], "step")
                    clk.advance(1000)
                    s.begin(wids[1], "io")
                    clk.advance(1000)
                    s.end(wids[1])
                    clk.advance(700)
                    s.end(wids[0])
                    clk.advance(300)
            s.result()
            sink.close()
        assert server.wait_idle(10), server.stats()
        rep = fleet_sess.result()
    finally:
        fleet_sess.stop()
        server.close()

    merged = _assert_fleet_equals_journals(rep, fleet_dir, n_min=2.0)
    assert len(merged) == 800
    # provenance survives the offline replay
    src = FleetSource.from_fleet_dir(fleet_dir)
    assert [h.host_id for h in src.hosts] == ["host0", "host1"]
    assert src.worker_hosts() == ["host0"] * 2 + ["host1"] * 2
    rep2 = ProfileSession(src, n_min=2.0).result()
    assert rep2.worker_hosts == rep.worker_hosts
    assert _ranked(rep2) == _ranked(rep)

    # meta sidecars carry the resume state the replay just used
    metas = sorted(f for f in os.listdir(fleet_dir)
                   if f.endswith(".meta.json"))
    assert len(metas) == 2
    with open(os.path.join(fleet_dir, metas[0])) as f:
        meta = json.load(f)
    assert meta["host_id"] in ("host0", "host1")
    assert meta["num_workers"] == 2
    assert meta["next_seq"] >= 1
    assert any(t and t[0] in ("step", "io") for t in meta["tags"])


def test_from_fleet_dir_missing_journal_raises(tmp_path):
    """A meta whose journal file is gone must fail loudly — a silent skip
    would drop the whole host from the offline replay unnoticed."""
    fleet_dir = tmp_path / "fleet"
    fleet_dir.mkdir()
    with open(fleet_dir / "h.meta.json", "w") as f:
        json.dump({"host_id": "h", "host_index": 0, "num_workers": 1,
                   "journal": "h.spill", "instance": "i"}, f)
    import pytest
    with pytest.raises(FileNotFoundError):
        FleetSource.from_fleet_dir(str(fleet_dir))


def test_journal_meta_seeds_only_empty_registries(tmp_path):
    """Resume seeding must not scramble a session that already interned
    tags: non-empty registries are left alone."""
    journal = str(tmp_path / "j.journal")
    sink = RemoteSink(("127.0.0.1", 1), "h", journal=journal,
                      max_reconnects=0, connect_timeout=0.05)
    # fabricate a meta as a previous incarnation would have left it
    sink.close(timeout=0.1)
    with open(journal + ".meta.json") as f:
        meta = json.load(f)
    meta["tags"] = [["old_tag", "m:1"]]
    meta["instance"] = "prev-instance"
    with open(journal + ".meta.json", "w") as f:
        json.dump(meta, f)

    from repro.core.tracer import StackRegistry, TagRegistry
    empty = TagRegistry()
    s2 = RemoteSink(("127.0.0.1", 1), "h", journal=journal, tags=empty,
                    stacks=StackRegistry(), max_reconnects=0,
                    connect_timeout=0.05)
    assert s2.instance == "prev-instance"
    assert list(empty.names) == ["old_tag"]
    s2.close(timeout=0.1)

    busy = TagRegistry()
    busy.intern("mine", "m:0")
    s3 = RemoteSink(("127.0.0.1", 1), "h", journal=journal, tags=busy,
                    stacks=StackRegistry(), max_reconnects=0,
                    connect_timeout=0.05)
    assert list(busy.names) == ["mine"]     # untouched
    s3.close(timeout=0.1)


def test_resume_with_fewer_workers_keeps_history(tmp_path):
    """A resumed incarnation that registers fewer workers than the dead
    one must still HELLO the union worker table (persisted in the meta),
    or the replayed history's rows for the missing workers would be
    silently filtered as bad_rows."""
    journal = str(tmp_path / "w.journal")
    server = IngestServer()
    server.start()
    try:
        # phase 1: two workers, rows on both
        clk = FakeClock()
        s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
        w0 = s.register_worker("a")
        w1 = s.register_worker("b")
        sink = attach_remote(s, server.address, host_id="h",
                             clock_offset_ns=0, journal=journal)
        _stream_spans(s, w0, clk, 10)
        _stream_spans(s, w1, clk, 10)
        s.result()
        sink.close()
        server.close()

        # server loses everything; phase 2 registers only ONE worker
        server2 = IngestServer(server.address)
        server2.start()
        clk2 = FakeClock()
        clk2.t = 10_000_000
        s2 = ProfileSession(n_min=1.0, clock=clk2, drain_interval=0.001)
        v0 = s2.register_worker("a")
        sink2 = attach_remote(s2, server2.address, host_id="h",
                              clock_offset_ns=0, journal=journal)
        _stream_spans(s2, v0, clk2, 5)
        s2.result()
        sink2.close()
        assert server2.wait_idle(10), server2.stats()
        st = server2.stats()
        # the full replay (ack 0) landed: worker b's rows included
        assert st["bad_rows"] == 0, st
        assert st["rows_in"] == 50, st
        assert server2.source.hosts[0].num_workers == 2
        assert server2.source.hosts[0].worker_names == ["a", "b"]
        server = server2
    finally:
        server.close()


def test_orphaned_journal_without_meta_starts_clean(tmp_path):
    """Journal blocks with no meta sidecar are a dead capture (no nonce
    to resume): the sink must truncate instead of replaying them into the
    new capture."""
    journal = str(tmp_path / "o.journal")
    z = [np.asarray(a) for a in
         ([10, 20], np.zeros(2, np.int32), [1, -1],
          np.full(2, -1, np.int32), np.full(2, -1, np.int32))]
    sink = RemoteSink(("127.0.0.1", 1), "h", journal=journal,
                      max_reconnects=0, connect_timeout=0.05)
    sink.append_columns(*z)
    sink.close(timeout=0.2)
    assert os.path.getsize(journal) > 0
    os.remove(journal + ".meta.json")
    s2 = RemoteSink(("127.0.0.1", 1), "h", journal=journal,
                    max_reconnects=0, connect_timeout=0.05)
    assert s2._next_seq == 0
    from repro.core import SpillStore
    assert SpillStore.open_readonly(journal).blocks == 0
    # the dead capture's history is rotated aside, never destroyed
    orphans = [p for p in os.listdir(tmp_path) if ".orphaned" in p]
    assert len(orphans) == 1
    assert SpillStore.open_readonly(str(tmp_path / orphans[0])).blocks == 1
    s2.close(timeout=0.2)


def test_accepted_seq_gap_journals_filler_blocks(tmp_path):
    """An accepted gap (lost chunks the server moves past) must keep the
    fleet_dir journal's block-index == seq invariant via empty filler
    blocks — otherwise a restarted server's ack floor would re-accept
    already-folded seqs."""
    import socket as socket_mod
    from repro.fleet import wire
    fleet_dir = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=fleet_dir)
    server.start()
    addr = server.address
    cols = (np.asarray([10, 20], np.int64), np.zeros(2, np.int32),
            np.asarray([1, -1], np.int8), np.full(2, -1, np.int32),
            np.full(2, -1, np.int32))
    try:
        sock = socket_mod.create_connection(addr, timeout=5)
        f = sock.makefile("rwb")
        f.write(wire.encode_hello("gappy", 1, ["w0"], t_client_ns=0,
                                  clock_offset_ns=0, instance="inst-1"))
        f.flush()
        kind, payload = wire.read_frame(f)
        epoch = wire.decode_json(payload)["epoch"]
        f.write(wire.encode_chunk(0, wire.MERGED_SHARD, epoch, 0, *cols))
        # seqs 1 and 2 never sent: an accepted gap
        c2 = tuple(np.asarray([30, 40], np.int64) if i == 0 else c
                   for i, c in enumerate(cols))
        f.write(wire.encode_chunk(0, wire.MERGED_SHARD, epoch, 3, *c2))
        f.write(wire.encode_bye(rows_sent=4, chunks_sent=2))
        f.flush()
        _wait(lambda: server.stats()["lost_chunks"] == 2)
        f.close()
        sock.close()
        server.wait_idle(10)
        server.close()

        # restart: the floor must be 4 (past the gap), not 2
        server = IngestServer(addr, fleet_dir=fleet_dir)
        server.start()
        sock = socket_mod.create_connection(addr, timeout=5)
        f = sock.makefile("rwb")
        f.write(wire.encode_hello("gappy", 1, ["w0"], t_client_ns=0,
                                  clock_offset_ns=0, instance="inst-1"))
        f.flush()
        kind, payload = wire.read_frame(f)
        w = wire.decode_json(payload)
        assert w["ack_seq"] == 4, w
        # the backfill re-fed only the 4 real rows, fillers skipped
        assert server.stats()["backfilled_rows"] == 4
        f.close()
        sock.close()
    finally:
        server.close()


def test_v1_producer_handshake_gets_v1_welcome(tmp_path):
    """A v1 producer (old build) must be able to complete the handshake:
    the server stamps its WELCOME with the peer's schema version."""
    import socket as socket_mod
    import struct as struct_mod
    from repro.fleet import wire
    server = IngestServer()
    server.start()
    try:
        sock = socket_mod.create_connection(server.address, timeout=5)
        f = sock.makefile("rwb")
        hello = {"magic": wire.MAGIC, "wire_version": 1, "host_id": "old",
                 "num_workers": 1, "worker_names": ["w0"],
                 "t_client_ns": 0, "clock_offset_ns": 0}
        payload = json.dumps(hello).encode()
        f.write(struct_mod.pack("<BBHI", wire.HELLO, 0, 1, len(payload))
                + payload)
        f.flush()
        hdr = f.read(8)
        kind, flags, version, length = struct_mod.unpack("<BBHI", hdr)
        assert kind == wire.WELCOME
        assert version == 1                 # a v1 decoder accepts this
        assert flags == 0                   # and it is never compressed
        w = json.loads(f.read(length))
        assert w["codec"] == "raw"          # no codecs offered -> raw
        f.close()
        sock.close()
    finally:
        server.close()


def test_truncated_journal_replay_floor_survives(tmp_path):
    """Torn tail in the producer journal (crash mid-append): the resumed
    sink's seq floor excludes the torn block, and the server receives a
    gapless, bit-exact stream of the surviving blocks."""
    journal = str(tmp_path / "t.journal")
    z = [np.asarray(a) for a in
         ([10, 20], np.zeros(2, np.int32), [1, -1],
          np.full(2, -1, np.int32), np.full(2, -1, np.int32))]
    sink = RemoteSink(("127.0.0.1", 1), "h", journal=journal,
                      max_reconnects=0, connect_timeout=0.05)
    for k in range(4):
        cols = [np.asarray([10 + 100 * k, 20 + 100 * k], np.int64)] + z[1:]
        sink.append_columns(*cols)
    assert sink._next_seq == 4
    sink.close(timeout=0.2)
    # rip into the last block's payload
    size = os.path.getsize(journal)
    with open(journal, "r+b") as f:
        f.truncate(size - 7)

    server = IngestServer()
    server.start()
    try:
        s2 = RemoteSink(server.address, "h", num_workers=1,
                        worker_names=["w0"], clock_offset_ns=0,
                        journal=journal)
        assert s2._next_seq == 3            # floor excludes the torn block
        s2.start()                          # connect: ack 0 -> replay all 3
        assert s2.flush(5.0)
        _wait(lambda: server.source.stats()["rows_in"] == 6)
        # the re-recorded 4th chunk continues the numbering gaplessly
        cols = [np.asarray([1000, 1100], np.int64)] + z[1:]
        s2.append_columns(*cols)
        s2.close()
        assert server.wait_idle(10), server.stats()
        st = server.stats()
        assert st["rows_in"] == 8
        assert st["lost_chunks"] == 0 and st["duplicate_chunks"] == 0, st
    finally:
        server.close()
