"""Columnar slice-table IR: round-trips, growable buffer, vectorized merge
vs the retained Python-loop oracle, threads_av fallback consistency, and
adversarial event streams across all four backends."""
import numpy as np
import pytest

from repro.core import (ACTIVATE, DEACTIVATE, CriticalBuffer, CriticalSlice,
                        EventLog, SliceTable, Tracer, compute, compute_numpy,
                        detect_offline, merge_table, simulate_samples)
from repro.core import detector as detector_lib
from repro.core.events import NO_STACK, NO_TAG

try:                                   # `python -m pytest` from the repo root
    from tests.test_tracer import FakeClock
except ImportError:                    # plain `pytest` (tests/ on sys.path)
    from test_tracer import FakeClock

BACKENDS = ("numpy", "stream", "vector", "pallas")


def _mklog(events, num_workers):
    """events: list of (t_ns, worker, delta)."""
    e = len(events)
    t = np.asarray([ev[0] for ev in events], np.int64)
    w = np.asarray([ev[1] for ev in events], np.int32)
    d = np.asarray([ev[2] for ev in events], np.int8)
    order = np.argsort(t, kind="stable")
    return EventLog(t[order], w[order], d[order],
                    np.full(e, NO_TAG, np.int32),
                    np.full(e, NO_STACK, np.int32), num_workers)


def _random_workload(seed, workers=4, steps=40):
    """Traced workload with varying parallelism, tags and refined frames."""
    rng = np.random.default_rng(seed)
    clk = FakeClock()
    tr = Tracer(n_min=workers - 0.5, clock=clk)
    wids = [tr.register_worker(f"w{i}") for i in range(workers)]
    tags = ["alpha", "beta", "gamma", "delta"]
    for _ in range(steps):
        active = rng.choice(wids, size=int(rng.integers(1, workers + 1)),
                            replace=False)
        for wid in active:
            tr.begin(int(wid), str(rng.choice(tags)))
            if rng.random() < 0.3:
                tr.push(int(wid), "inner")
        clk.advance(int(rng.integers(10_000, 1_000_000)))
        for wid in active:
            tr.end(int(wid))
        clk.advance(int(rng.integers(1_000, 100_000)))
    return tr


# ---------------------------------------------------------------------------
# table / buffer mechanics
# ---------------------------------------------------------------------------

def test_table_record_roundtrip():
    rows = [CriticalSlice(1, 10, 20, 1e-6, 1.5, 0, 2),
            CriticalSlice(0, 15, 40, 2e-6, 1.1, -1, 1)]
    t = SliceTable.from_records(rows)
    assert len(t) == 2
    t.validate()
    assert t.to_records() == rows
    assert t[1] == rows[1]
    assert list(t) == rows


def test_table_filter_and_critical():
    t = SliceTable.from_arrays([0, 1, 2], [0, 10, 20], [5, 15, 25],
                               [1e-6, 2e-6, 3e-6], [1.0, 2.0, 3.0],
                               [0, 1, 2], [1, 2, 3])
    crit = t.critical(2.5)
    assert len(crit) == 2
    assert crit.n_min == 2.5
    np.testing.assert_array_equal(crit.worker, [0, 1])
    sub = t[t.worker >= 1]
    assert len(sub) == 2
    np.testing.assert_array_equal(sub.start_ns, [10, 20])
    assert len(SliceTable.empty()) == 0
    assert len(SliceTable.concat([t, sub])) == 5


def test_critical_buffer_grows_and_indexes():
    buf = CriticalBuffer(capacity=2)
    for i in range(100):
        buf.append(i % 3, i * 10, i * 10 + 5, i * 1e-9, 1.0 + i, i, 1)
    assert len(buf) == 100
    assert buf[0].start_ns == 0
    assert buf[-1].start_ns == 990
    assert buf[7].threads_av == pytest.approx(8.0)
    with pytest.raises(IndexError):
        buf[100]
    t = buf.table()
    assert len(t) == 100
    np.testing.assert_array_equal(t.worker, np.arange(100) % 3)


def test_tracer_critical_is_columnar():
    tr = _random_workload(3)
    assert isinstance(tr.critical, CriticalBuffer)
    t = tr.critical.table()
    assert len(t) == len(tr.critical)
    if len(t):
        assert t[0] == tr.critical[0]


# ---------------------------------------------------------------------------
# vectorized merge == Python-loop oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_merge_table_matches_python_oracle(backend):
    tr = _random_workload(0)
    log = tr.freeze()
    n_min = tr._resolved_n_min()
    samples = simulate_samples(log, 50_000, n_min)
    res = compute(log, backend=backend)
    crit = res.critical_table(n_min)
    assert len(crit) > 0
    profiles, attached = merge_table(crit, samples, tr.stacks, n_min)
    oracle, attached_o = detector_lib._merge_python(
        crit.to_records(), samples, tr.stacks, n_min)
    assert attached == attached_o > 0
    assert [p.stack for p in profiles] == list(oracle.keys())
    for p in profiles:
        o = oracle[p.stack]
        assert p.slices == o.slices
        assert p.cmetric == pytest.approx(o.cmetric, rel=1e-9, abs=1e-15)
        assert p.tag_counts == o.tag_counts
        assert p.stack_top_counts == o.stack_top_counts


def test_merge_table_no_samples_stack_top_fallback():
    tr = _random_workload(5)
    log = tr.freeze()
    n_min = tr._resolved_n_min()
    crit = compute_numpy(log).critical_table(n_min)
    profiles, attached = merge_table(crit, None, tr.stacks, n_min)
    oracle, _ = detector_lib._merge_python(crit.to_records(), None,
                                           tr.stacks, n_min)
    assert attached == 0
    for p in profiles:
        assert p.stack_top_counts == oracle[p.stack].stack_top_counts
        assert sum(p.tag_counts.values()) == 0


def test_merge_table_boundary_sample_matches_oracle():
    """A sample exactly on a shared slice boundary (end of one slice ==
    start of the next, same worker) attaches to BOTH slices in the per-slice
    oracle's inclusive [start, end] check — the vectorized attachment must
    reproduce that, including zero-duration slices stacked on the same ns."""
    from repro.core import SampleBuffer, StackRegistry
    stacks = StackRegistry()
    a = stacks.intern((1,))
    b = stacks.intern((2,))
    table = SliceTable.from_arrays(
        worker=[0, 0, 0, 1], start_ns=[100, 200, 200, 150],
        end_ns=[200, 200, 300, 250], cm=[1e-6, 0.0, 2e-6, 1e-6],
        threads_av=[1.0, 1.0, 1.0, 1.0], stack_id=[a, b, a, b],
        n_at_exit=[1, 1, 1, 1])
    buf = SampleBuffer()
    buf.append(200, 0, 7)      # on the triple boundary: slices 0, 1 and 2
    buf.append(250, 1, 8)      # on worker 1's slice end
    buf.append(99, 0, 9)       # before any slice: unattached
    profiles, attached = merge_table(table, buf, stacks, n_min=2.0)
    oracle, attached_o = detector_lib._merge_python(table.to_records(), buf,
                                                    stacks, n_min=2.0)
    assert attached == attached_o == 4
    for p in profiles:
        o = oracle[p.stack]
        assert p.tag_counts == o.tag_counts
        assert p.stack_top_counts == o.stack_top_counts


def test_merge_table_pallas_hist_matches_bincount():
    tr = _random_workload(7)
    log = tr.freeze()
    n_min = tr._resolved_n_min()
    samples = simulate_samples(log, 50_000, n_min)
    crit = compute_numpy(log).critical_table(n_min)
    a, _ = merge_table(crit, samples, tr.stacks, n_min, use_pallas_hist=False)
    b, _ = merge_table(crit, samples, tr.stacks, n_min, use_pallas_hist=True)
    assert [p.stack for p in a] == [p.stack for p in b]
    for pa, pb in zip(a, b):
        assert pa.tag_counts == pb.tag_counts


def test_reports_equivalent_across_backends():
    tr = _random_workload(1)
    log = tr.freeze()
    n_min = tr._resolved_n_min()
    reports = {b: detect_offline(log, tr.tags, tr.stacks, n_min,
                                 sample_dt_ns=50_000, backend=b)
               for b in BACKENDS}
    r0 = reports["numpy"]
    assert r0.paths
    for b, r in reports.items():
        np.testing.assert_allclose(r.per_worker, r0.per_worker, rtol=1e-4,
                                   atol=1e-6, err_msg=b)
        assert r.total_critical == r0.total_critical, b
        assert r.total_slices == r0.total_slices, b
        assert [r.path_str(p) for p in r.paths] == \
            [r0.path_str(p) for p in r0.paths], b
        for p, p0 in zip(r.paths, r0.paths):
            assert p.cmetric == pytest.approx(p0.cmetric, rel=1e-3,
                                              abs=1e-9), b
            assert p.slices == p0.slices, b


# ---------------------------------------------------------------------------
# threads_av fallback: zero-CMetric slices (regression — vector/pallas used
# to hardcode 1.0 while the numpy oracle used the exit-time active count)
# ---------------------------------------------------------------------------

def test_threads_av_zero_cm_fallback_consistent():
    # w1 runs a zero-duration slice while w0 is active: slice_cm == 0, and
    # the active count at w1's exit is 2 (itself + w0)
    log = _mklog([(0, 0, ACTIVATE), (5_000_000, 1, ACTIVATE),
                  (5_000_000, 1, DEACTIVATE), (10_000_000, 0, DEACTIVATE)], 2)
    vals = {}
    for b in BACKENDS:
        res = compute(log, backend=b)
        i = list(res.slice_worker).index(1)
        assert res.slice_cm[i] == pytest.approx(0.0, abs=1e-12)
        vals[b] = float(res.slice_threads_av[i])
        assert res.table.n_at_exit[i] == 2
    assert all(v == pytest.approx(2.0) for v in vals.values()), vals
    # the slice must be equally (non-)critical under every backend
    for n_min in (1.5, 2.5):
        crits = {b: int(np.sum(compute(log, backend=b).critical_mask(n_min)))
                 for b in BACKENDS}
        assert len(set(crits.values())) == 1, (n_min, crits)


# ---------------------------------------------------------------------------
# adversarial event streams (paper §3.2 tolerance), all four backends
# ---------------------------------------------------------------------------

def _dirty_logs():
    ms = 1_000_000
    return {
        "double_activate": _mklog(
            [(0, 0, ACTIVATE), (1 * ms, 0, ACTIVATE), (2 * ms, 1, ACTIVATE),
             (3 * ms, 0, DEACTIVATE), (4 * ms, 1, DEACTIVATE)], 2),
        "unmatched_deactivate": _mklog(
            [(0, 0, DEACTIVATE), (1 * ms, 0, ACTIVATE), (2 * ms, 1, ACTIVATE),
             (3 * ms, 1, DEACTIVATE), (4 * ms, 1, DEACTIVATE),
             (5 * ms, 0, DEACTIVATE)], 2),
        "trailing_open": _mklog(
            [(0, 0, ACTIVATE), (1 * ms, 0, DEACTIVATE),
             (2 * ms, 1, ACTIVATE)], 2),
    }


def test_sanitize_matches_live_tracer_tolerance():
    for name, log in _dirty_logs().items():
        clean = log.sanitize()
        clean.validate()          # alternation restored
        # the live probe body applied to the same stream keeps the same
        # events: per-worker CMetrics agree exactly
        tr = Tracer(n_min=0.0)    # n_min 0: no critical capture needed
        for _ in range(log.num_workers):
            tr.register_worker("w")
        for t, w, d in zip(log.times, log.workers, log.deltas):
            tr.ingest(int(t), int(w), int(d))
        res = compute_numpy(clean)
        np.testing.assert_allclose(res.per_worker, tr.per_worker_cm(),
                                   rtol=1e-9, err_msg=name)
        assert len(tr.freeze()) == len(clean), name


def test_sanitize_vectorized_matches_tracer_on_random_dirty_logs():
    """Fuzz the greedy-filter equivalence: the vectorised run-collapse must
    keep exactly the events the live probe body would have recorded."""
    rng = np.random.default_rng(11)
    for _ in range(5):
        e = 200
        t = np.sort(rng.integers(0, 10**7, e)).astype(np.int64)
        w = rng.integers(0, 5, e).astype(np.int32)
        d = rng.choice([1, -1], e).astype(np.int8)
        log = _mklog(list(zip(t.tolist(), w.tolist(), d.tolist())), 5)
        clean = log.sanitize()
        clean.validate()
        tr = Tracer(n_min=0.0)
        for _ in range(5):
            tr.register_worker("w")
        for ti, wi, di in zip(log.times, log.workers, log.deltas):
            tr.ingest(int(ti), int(wi), int(di))
        frozen = tr.freeze()
        assert len(frozen) == len(clean)
        np.testing.assert_array_equal(frozen.times, clean.times)
        np.testing.assert_array_equal(frozen.workers, clean.workers)
        np.testing.assert_array_equal(frozen.deltas, clean.deltas)
        res = compute_numpy(clean)
        np.testing.assert_allclose(res.per_worker, tr.per_worker_cm(),
                                   rtol=1e-9)


def test_sanitize_noop_on_clean_log():
    tr = _random_workload(2)
    log = tr.freeze()
    assert log.is_well_formed()
    assert log.sanitize() is log


@pytest.mark.parametrize("case", ["double_activate", "unmatched_deactivate",
                                  "trailing_open"])
def test_adversarial_streams_agree_across_backends(case):
    log = _dirty_logs()[case]
    from repro.core.tracer import StackRegistry, TagRegistry
    reports = {b: detect_offline(log, TagRegistry(), StackRegistry(),
                                 n_min=1.5, backend=b) for b in BACKENDS}
    r0 = reports["numpy"]
    for b, r in reports.items():
        np.testing.assert_allclose(r.per_worker, r0.per_worker, rtol=1e-4,
                                   atol=1e-9, err_msg=(case, b))
        assert r.total_slices == r0.total_slices, (case, b)
        assert r.total_critical == r0.total_critical, (case, b)


def test_gapp_offline_report_cross_validates_live():
    from repro.core import Gapp
    clk = FakeClock()
    g = Gapp(n_min=1.9, clock=clk)
    ws = [g.register_worker(f"t{i}") for i in range(3)]
    for _ in range(6):
        for w in ws[:2]:
            g.begin(w, "parallel")
        clk.advance(2_000_000)
        for w in ws[:2]:
            g.end(w)
        g.begin(ws[2], "serial")
        clk.advance(5_000_000)
        g.end(ws[2])
    live = g.report()
    for backend in ("numpy", "vector"):
        off = g.offline_report(backend=backend)
        np.testing.assert_allclose(off.per_worker, live.per_worker,
                                   rtol=1e-4, atol=1e-9)
        assert off.total_critical == live.total_critical
        assert [off.path_str(p) for p in off.paths] == \
            [live.path_str(p) for p in live.paths]


@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_log_all_backends(backend):
    empty = _mklog([], 3)
    res = compute(empty, backend=backend)
    assert res.num_slices == 0
    assert res.per_worker.shape == (3,)
    assert res.per_worker.sum() == 0.0
    from repro.core.tracer import StackRegistry, TagRegistry
    rep = detect_offline(empty, TagRegistry(), StackRegistry(), n_min=1.5,
                         backend=backend)
    assert rep.paths == [] and rep.total_critical == 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_single_worker_log_all_backends(backend):
    ms = 1_000_000
    log = _mklog([(0, 0, ACTIVATE), (2 * ms, 0, DEACTIVATE),
                  (3 * ms, 0, ACTIVATE), (7 * ms, 0, DEACTIVATE)], 1)
    res = compute(log, backend=backend)
    assert res.num_slices == 2
    # a lone worker owns all elapsed busy time
    assert res.per_worker[0] == pytest.approx(6e-3, rel=1e-5)
    np.testing.assert_allclose(res.slice_threads_av, [1.0, 1.0], rtol=1e-5)
