"""Causal what-if engine: counterfactual projections + sensitivity sweep.

Acceptance properties (ISSUE 10):

* removing an exclusively-serial section projects its exact duration
  (the engine's "exact for serial sections" contract);
* a tag with zero critical CMetric projects speedup 1.0 — never a
  phantom gain;
* unknown targets / missing replay handles / bad shrink values fail
  loudly, not silently;
* host-targeted shrink works on multi-host fleet reports and refuses
  reports without host provenance;
* the counterfactual fold agrees across numpy and pallas backends;
* ``GET /api/whatif`` is byte-identical to the offline
  ``report.what_if(...).to_json()`` on the same fleet_dir.
"""
import json
import urllib.error

import numpy as np
import pytest

from repro.core import ProfileSession, Tracer, detect, detect_offline
from repro.core.report import JSON_SCHEMA_VERSION, render_text, to_json
from repro.core.whatif import WHATIF_SCHEMA_VERSION, warp_log
from repro.fleet import FleetSource, IngestServer, ProfilerService
from tests.test_service import _get, _populate
from tests.test_tracer import FakeClock

PAR_MS, SERIAL_MS, REPS = 2, 5, 8


def _serial_trace(n_min=1.9):
    """w0/w1 parallel bursts; w2 exclusively-serial io_phase sections.

    Removing io_phase is worth exactly REPS * SERIAL_MS of wall clock —
    ground truth by construction."""
    clk = FakeClock()
    tr = Tracer(n_min=n_min, clock=clk)
    w = [tr.register_worker(f"w{i}") for i in range(3)]
    for _ in range(REPS):
        tr.begin(w[0], "par")
        tr.begin(w[1], "par")
        clk.advance(PAR_MS * 1_000_000)
        tr.end(w[0])
        tr.end(w[1])
        tr.begin(w[2], "io_phase")
        clk.advance(SERIAL_MS * 1_000_000)
        tr.end(w[2])
    return tr


# ---------------------------------------------------------------------------
# exactness on serial sections
# ---------------------------------------------------------------------------

def test_remove_serial_section_is_exact():
    rep = detect(_serial_trace(), None, top_n=5)
    wi = rep.what_if("io_phase", shrink=0.0)
    truth = rep.total_time - REPS * SERIAL_MS * 1e-3
    assert wi.projected_total_s == pytest.approx(truth, abs=1e-12)
    assert wi.speedup == pytest.approx(rep.total_time / truth, rel=1e-9)
    assert wi.matched_slices == REPS
    assert wi.saved_s == pytest.approx(REPS * SERIAL_MS * 1e-3, abs=1e-12)
    # the projection is a real report: the serial path's weight is gone
    # (zero-duration slices may linger as zero-CMetric entries)
    for e in wi.ranking:
        if e["path"] == "io_phase":
            assert e["cmetric_s"] == pytest.approx(0.0, abs=1e-12)


def test_partial_shrink_scales_linearly():
    rep = detect(_serial_trace(), None, top_n=5)
    for shrink in (0.25, 0.5, 0.75):
        wi = rep.what_if("io_phase", shrink=shrink)
        truth = rep.total_time - (1 - shrink) * REPS * SERIAL_MS * 1e-3
        assert wi.projected_total_s == pytest.approx(truth, rel=1e-9)


def test_what_if_composes():
    """The counterfactual report carries its own replay handle."""
    rep = detect(_serial_trace(), None, top_n=5)
    wi = rep.what_if("io_phase", shrink=0.5)
    wi2 = wi.report.what_if("io_phase", shrink=0.0)
    truth = rep.total_time - REPS * SERIAL_MS * 1e-3
    assert wi2.projected_total_s == pytest.approx(truth, rel=1e-9)


def test_per_worker_shift_and_ranking_moves():
    rep = detect(_serial_trace(), None, top_n=5)
    wi = rep.what_if("io_phase", shrink=0.0)
    rows = {r["worker"]: r for r in wi.per_worker}
    assert rows["w2"]["delta_cmetric_s"] == pytest.approx(
        -REPS * SERIAL_MS * 1e-3, rel=1e-9)
    for e in wi.ranking:
        assert {"rank", "baseline_rank", "rank_delta"} <= set(e)


# ---------------------------------------------------------------------------
# edge cases: zero-CMetric tags, unknown targets, missing replay
# ---------------------------------------------------------------------------

def test_zero_cmetric_tag_projects_no_gain():
    """'par' runs at full parallelism — nothing critical, so shrinking it
    cannot shrink wall clock."""
    rep = detect(_serial_trace(), None, top_n=5)
    wi = rep.what_if("par", shrink=0.0)
    assert wi.matched_slices == 0
    assert wi.speedup == 1.0
    assert wi.projected_total_s == pytest.approx(rep.total_time)
    assert wi.to_doc()["saved_s"] == 0.0


def test_unknown_tag_raises_with_known_names():
    rep = detect(_serial_trace(), None, top_n=5)
    with pytest.raises(ValueError, match="io_phase"):
        rep.what_if("no_such_tag")


def test_report_without_replay_raises():
    rep = detect(_serial_trace(), None, top_n=5)
    rep.replay = None
    with pytest.raises(RuntimeError, match="replay"):
        rep.what_if("io_phase")
    with pytest.raises(RuntimeError, match="replay"):
        rep.sensitivity()


def test_shrink_and_target_validation():
    rep = detect(_serial_trace(), None, top_n=5)
    with pytest.raises(ValueError, match="shrink"):
        rep.what_if("io_phase", shrink=-0.1)
    with pytest.raises(ValueError, match="shrink"):
        rep.what_if("io_phase", shrink=1.5)
    with pytest.raises(ValueError, match="exactly one"):
        rep.what_if()
    with pytest.raises(ValueError, match="exactly one"):
        rep.what_if("io_phase", worker="w2")
    with pytest.raises(ValueError, match="host"):
        rep.what_if(host="nowhere")         # no host provenance


def test_path_rank_targeting_matches_tag_targeting():
    rep = detect(_serial_trace(), None, top_n=5)
    assert rep.path_str(rep.paths[0]) == "io_phase"
    by_tag = rep.what_if("io_phase", shrink=0.0)
    by_rank = rep.what_if(path=1, shrink=0.0)
    assert by_rank.projected_total_s == by_tag.projected_total_s
    with pytest.raises(ValueError, match="rank"):
        rep.what_if(path=99)


def test_worker_targeting():
    rep = detect(_serial_trace(), None, top_n=5)
    wi = rep.what_if(worker="w2", shrink=0.0)
    truth = rep.total_time - REPS * SERIAL_MS * 1e-3
    assert wi.projected_total_s == pytest.approx(truth, rel=1e-9)
    with pytest.raises(ValueError, match="unknown worker"):
        rep.what_if(worker="w9")


def test_warp_log_empty_and_no_target():
    tr = _serial_trace()
    log = tr.freeze().sanitize()
    warped, saved, n, comp = warp_log(
        log, np.zeros(0, np.int64), np.zeros(0, np.int64), 0.0)
    assert warped is log and saved == 0.0 and n == 0 and comp == 0.0


# ---------------------------------------------------------------------------
# backend parity
# ---------------------------------------------------------------------------

def test_numpy_vs_pallas_counterfactual_parity():
    tr = _serial_trace()
    log = tr.freeze()
    reps = {}
    for backend in ("numpy", "pallas"):
        r = detect_offline(log, tr.tags, tr.stacks, 1.9, backend=backend,
                           worker_names=tr.worker_names())
        reps[backend] = r.what_if("io_phase", shrink=0.0)
    a, b = reps["numpy"], reps["pallas"]
    assert a.matched_slices == b.matched_slices
    assert a.projected_total_s == pytest.approx(b.projected_total_s,
                                                rel=1e-6)
    assert a.speedup == pytest.approx(b.speedup, rel=1e-6)


# ---------------------------------------------------------------------------
# sensitivity sweep
# ---------------------------------------------------------------------------

def test_sensitivity_stable_ranking():
    # n_min=2.5 keeps the sweep's lowest threshold (x0.5 -> 1.25) above
    # the serial sections' threads_av of 1, so a *stable* ranking is the
    # correct expectation across every variant
    rep = detect(_serial_trace(n_min=2.5), None, top_n=5)
    sr = rep.sensitivity()
    assert sr.summary["variants"] == 5        # n_min sweep, no sampler
    assert sr.summary["stable"] is True
    assert sr.summary["top1_stability"] == 1.0
    assert sr.rank_stability["io_phase"]["baseline_rank"] == 1
    doc = sr.to_doc()
    assert doc["schema_version"] == WHATIF_SCHEMA_VERSION
    assert json.loads(sr.to_json()) == doc


def test_sensitivity_unknown_param_raises():
    rep = detect(_serial_trace(), None, top_n=5)
    with pytest.raises(ValueError, match="unknown sensitivity"):
        rep.sensitivity({"bogus_knob": (1.0,)})


def test_sensitivity_custom_scales():
    rep = detect(_serial_trace(), None, top_n=5)
    sr = rep.sensitivity({"n_min_scale": (1.0, 2.0)})
    assert sr.summary["variants"] == 2
    assert [v["value"] for v in sr.variants] == [1.0, 2.0]


# ---------------------------------------------------------------------------
# exporters: schema v4, additive what_if key, text section
# ---------------------------------------------------------------------------

def test_export_json_whatif_additive():
    rep = detect(_serial_trace(), None, top_n=5)
    plain = json.loads(to_json(rep))
    assert plain["schema_version"] == JSON_SCHEMA_VERSION == 4
    assert "what_if" not in plain
    doc = json.loads(to_json(rep, what_if=3, what_if_shrink=0.0))
    assert doc["what_if"]["shrink"] == 0.0
    projections = doc["what_if"]["projections"]
    assert projections[0]["rank"] == 1
    assert projections[0]["path"] == "io_phase"
    assert projections[0]["speedup"] > 1.0
    # dropping the extra key reproduces the plain document
    doc.pop("what_if")
    assert doc == plain


def test_render_text_whatif_section():
    rep = detect(_serial_trace(), None, top_n=5)
    assert "what-if" not in render_text(rep)
    txt = render_text(rep, what_if=2)
    assert "what-if projections" in txt
    assert "io_phase" in txt


# ---------------------------------------------------------------------------
# fleet: host targeting + /api/whatif byte-consistency
# ---------------------------------------------------------------------------

@pytest.fixture
def fleet_dir(tmp_path):
    d = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=d)
    server.start()
    try:
        _populate(server, tmp_path)
        assert server.wait_idle(10), server.stats()
    finally:
        server.close()
    return d


def _offline_rep(fleet_dir, n_min=2.0):
    return ProfileSession(FleetSource.from_fleet_dir(fleet_dir),
                          n_min=n_min).result()


def test_fleet_host_shrink(fleet_dir):
    rep = _offline_rep(fleet_dir)
    wi = rep.what_if(host="alpha", shrink=0.0)
    assert wi.selection == {"kind": "host", "value": "alpha", "workers":
                            wi.selection["workers"]}
    assert wi.matched_slices == 40
    assert wi.speedup > 1.0
    # host rows carry provenance in the per-worker shift
    assert {r.get("host") for r in wi.per_worker} == {"alpha", "beta"}
    with pytest.raises(ValueError, match="unknown host"):
        rep.what_if(host="gamma")


def test_fleet_tag_shrink_without_stacks(fleet_dir):
    """Fleet logs carry tags but no interned stacks — tag targeting must
    still resolve through the event stream."""
    rep = _offline_rep(fleet_dir)
    wi = rep.what_if("work-alpha", shrink=0.0)
    assert wi.matched_slices == 40
    assert wi.speedup > 1.0


def test_api_whatif_byte_equal_to_offline(fleet_dir):
    svc = ProfilerService.from_fleet_dir(fleet_dir, n_min=2.0).start()
    try:
        status, headers, body = _get(
            svc, "/api/whatif?tag=work-alpha&shrink=0")
        assert status == 200
        assert headers["Content-Type"].startswith("application/json")
        stats = svc.stats()
    finally:
        svc.close()
    want = _offline_rep(fleet_dir).what_if(
        "work-alpha", shrink=0.0).to_json().encode("utf-8")
    assert body == want
    doc = json.loads(body)
    assert doc["schema_version"] == WHATIF_SCHEMA_VERSION
    assert stats["whatif_folds"] == 1
    assert stats["whatif_fold_seconds_sum"] > 0.0


def test_api_whatif_error_paths(fleet_dir):
    svc = ProfilerService.from_fleet_dir(fleet_dir, n_min=2.0).start()
    try:
        for path, code in (
                ("/api/whatif", 400),                     # no target
                ("/api/whatif?tag=a&worker=b", 400),      # two targets
                ("/api/whatif?tag=work-alpha&shrink=2", 400),
                ("/api/whatif?tag=nope", 404),            # unknown tag
                ("/api/whatif?host=gamma", 404),          # unknown host
        ):
            with pytest.raises(urllib.error.HTTPError) as ei:
                _get(svc, path)
            assert ei.value.code == code, path
    finally:
        svc.close()
