"""Carry-resumable chunked fold: any chunk partition == whole-log oracle.

The contract (ISSUE 2 tentpole): ``fold_chunk(carry, chunk)`` over *every*
partition of a log — including size-1 chunks and chunk boundaries that cut
through open timeslices — must reproduce ``compute_numpy`` on the whole
log, bit-equal (float64) for the ``numpy`` chunk backend and within float32
tolerance for the device backends, across all four registered backends.
"""
import numpy as np
import pytest

try:                                   # `python -m pytest` from the repo root
    from tests.conftest import given, settings, st
except ImportError:                    # plain `pytest` (tests/ on sys.path)
    from conftest import given, settings, st

from repro.core import (EventLog, FoldCarry, SliceTable, StackRegistry,
                        TagRegistry, backends_with_fold_chunk, compute_numpy,
                        detect_offline, fold_chunk, sanitize_chunk,
                        synthetic_log)

ALL_BACKENDS = ("numpy", "stream", "vector", "pallas")


def _fold_partition(log, splits, backend):
    """Run the chunk fold over the given chunk sizes; returns (carry, table)."""
    carry = FoldCarry.init(log.num_workers)
    parts = []
    lo = 0
    for s in splits:
        hi = min(lo + s, len(log))
        carry, tbl = fold_chunk(carry, log.chunk(lo, hi), backend=backend)
        parts.append(tbl)
        lo = hi
        if lo >= len(log):
            break
    if lo < len(log):
        carry, tbl = fold_chunk(carry, log.chunk(lo, len(log)),
                                backend=backend)
        parts.append(tbl)
    return carry, SliceTable.concat(parts)


def _assert_matches_oracle(log, carry, tbl, exact):
    oracle = compute_numpy(log)
    assert carry.slices == oracle.num_slices == len(tbl)
    if exact:
        # float64 numpy chunk fold: bit-equal to the oracle, any split
        np.testing.assert_array_equal(carry.cm_hash, oracle.per_worker)
        assert carry.idle == oracle.idle_time
        assert carry.total_time == oracle.total_time
        for col in ("worker", "start_ns", "end_ns", "cm", "threads_av",
                    "n_at_exit"):
            np.testing.assert_array_equal(getattr(tbl, col),
                                          getattr(oracle.table, col),
                                          err_msg=col)
    else:
        np.testing.assert_allclose(carry.cm_hash, oracle.per_worker,
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(carry.idle, oracle.idle_time, rtol=1e-4,
                                   atol=1e-6)
        np.testing.assert_allclose(tbl.cm, oracle.table.cm, rtol=1e-3,
                                   atol=1e-6)
        np.testing.assert_array_equal(tbl.worker, oracle.table.worker)


def test_all_backends_register_fold_chunk():
    assert set(ALL_BACKENDS) <= set(backends_with_fold_chunk())


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_deterministic_partitions_match_oracle(backend):
    rng = np.random.default_rng(3)
    log = synthetic_log(rng, 5, 30)        # 300 events
    e = len(log)
    partitions = [
        [e],                               # single chunk == whole log
        [1] * e,                           # size-1 chunks
        [7] * (e // 7 + 1),                # boundary mid-timeslice
        [3, 1, e],                         # ragged
        [e // 2, e],                       # one cut
    ]
    for splits in partitions:
        carry, tbl = _fold_partition(log, splits, backend)
        _assert_matches_oracle(log, carry, tbl, exact=backend == "numpy")


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_chunk_boundary_mid_timeslice(backend):
    """A cut between a worker's ACTIVATE and its DEACTIVATE exercises the
    carry's local_cm/slice_start/open maps explicitly."""
    from repro.core.events import ACTIVATE, DEACTIVATE, NO_STACK, NO_TAG
    ev = [(0, 0, ACTIVATE), (2, 1, ACTIVATE), (5, 1, DEACTIVATE),
          (9, 0, DEACTIVATE), (11, 0, ACTIVATE), (15, 0, DEACTIVATE)]
    t, w, d = zip(*ev)
    log = EventLog(
        times=(np.asarray(t, np.float64) * 1e9).astype(np.int64),
        workers=np.asarray(w, np.int32),
        deltas=np.asarray(d, np.int8),
        tags=np.full(len(ev), NO_TAG, np.int32),
        stacks=np.full(len(ev), NO_STACK, np.int32),
        num_workers=2)
    # cut inside w0's [0,9) slice and inside its [11,15) slice
    for splits in ([2, 2, 2], [1, 4, 1], [3, 2, 1]):
        carry, tbl = _fold_partition(log, splits, backend)
        _assert_matches_oracle(log, carry, tbl, exact=backend == "numpy")
        assert not carry.open.any()        # every slice closed at the end


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 25), st.integers(0, 10_000),
       st.integers(1, 60))
def test_random_partitions_match_oracle_all_backends(num_workers, slices,
                                                     seed, chunk):
    """Hypothesis property: for random logs and random chunk sizes, the
    chunked fold equals the whole-log numpy oracle on all four backends."""
    rng = np.random.default_rng(seed)
    log = synthetic_log(rng, num_workers, slices)
    e = len(log)
    splits = []
    lo = 0
    srng = np.random.default_rng(seed + 1)
    while lo < e:
        s = int(srng.integers(1, chunk + 1))
        splits.append(s)
        lo += s
    for backend in ALL_BACKENDS:
        carry, tbl = _fold_partition(log, splits, backend)
        _assert_matches_oracle(log, carry, tbl, exact=backend == "numpy")


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 20), st.integers(0, 10_000))
def test_carry_is_exactly_table1_state(num_workers, slices, seed):
    """Mid-stream, the carry equals the oracle's eBPF-map state recomputed
    on the prefix: global_cm, idle, cm_hash, thread_count, open mask."""
    rng = np.random.default_rng(seed)
    log = synthetic_log(rng, num_workers, slices)
    e = len(log)
    cut = max(1, e // 3)
    carry = FoldCarry.init(log.num_workers)
    carry, _ = fold_chunk(carry, log.chunk(0, cut), backend="numpy")
    prefix = log.chunk(0, cut)
    res = compute_numpy(prefix)
    np.testing.assert_array_equal(carry.cm_hash, res.per_worker)
    assert carry.idle == res.idle_time
    assert carry.thread_count == int(prefix.deltas.astype(np.int64).sum())
    open_expect = np.zeros(log.num_workers, bool)
    for wi, di in zip(prefix.workers, prefix.deltas):
        open_expect[wi] = di == 1
    np.testing.assert_array_equal(carry.open, open_expect)


def test_sanitize_chunked_equals_whole_log():
    """Chunk-wise sanitize with carried open state keeps exactly the events
    whole-log sanitize keeps, for any chunking of a dirty stream."""
    from repro.core.events import NO_STACK, NO_TAG
    rng = np.random.default_rng(5)
    e = 300
    t = np.sort(rng.integers(0, 10**7, e)).astype(np.int64)
    w = rng.integers(0, 4, e).astype(np.int32)
    d = rng.choice([1, -1], e).astype(np.int8)
    log = EventLog(t, w, d, np.full(e, NO_TAG, np.int32),
                   np.full(e, NO_STACK, np.int32), 4)
    whole = log.sanitize()
    for chunk in (1, 7, 64, e):
        active = np.zeros(4, bool)
        parts = []
        for lo in range(0, e, chunk):
            part, active, _ = sanitize_chunk(log.chunk(lo, lo + chunk),
                                             active)
            parts.append(part)
        times = np.concatenate([p.times for p in parts])
        deltas = np.concatenate([p.deltas for p in parts])
        workers = np.concatenate([p.workers for p in parts])
        np.testing.assert_array_equal(times, whole.times)
        np.testing.assert_array_equal(deltas, whole.deltas)
        np.testing.assert_array_equal(workers, whole.workers)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_detect_offline_streaming_equals_whole(backend):
    """detect_offline(chunk_events=...) == detect_offline on the same log:
    same ranking, same per-worker CMetrics, same critical count."""
    rng = np.random.default_rng(11)
    log = synthetic_log(rng, 8, 60, skew=np.r_[np.ones(7), 8.0])
    tags, stacks = TagRegistry(), StackRegistry()
    n_min = 4.0
    whole = detect_offline(log, tags, stacks, n_min, sample_dt_ns=None,
                           backend=backend)
    for chunk in (17, 128, len(log)):
        part = detect_offline(log, tags, stacks, n_min, sample_dt_ns=None,
                              backend=backend, chunk_events=chunk)
        rtol = 0 if backend == "numpy" else 1e-4
        np.testing.assert_allclose(part.per_worker, whole.per_worker,
                                   rtol=rtol, atol=1e-9)
        assert part.total_slices == whole.total_slices
        if backend == "numpy":
            # float64 chunk fold: the report is *identical*
            assert part.total_critical == whole.total_critical
            assert [p.stack for p in part.paths] == [p.stack
                                                     for p in whole.paths]
            assert part.idle_time == whole.idle_time
            assert part.total_time == whole.total_time
        else:
            # float32 backends: a slice sitting exactly on the n_min
            # threshold may flip under the different summation order
            assert abs(part.total_critical - whole.total_critical) <= 2


def test_detect_offline_streaming_sanitizes_dirty_logs():
    """The streaming path applies §3.2 tolerance chunk-wise: dirty streams
    produce the same report as the whole-log sanitize+compute route."""
    from repro.core.events import NO_STACK, NO_TAG
    rng = np.random.default_rng(7)
    e = 400
    t = np.sort(rng.integers(0, 10**8, e)).astype(np.int64)
    w = rng.integers(0, 5, e).astype(np.int32)
    d = rng.choice([1, -1], e).astype(np.int8)
    log = EventLog(t, w, d, np.full(e, NO_TAG, np.int32),
                   np.full(e, NO_STACK, np.int32), 5)
    tags, stacks = TagRegistry(), StackRegistry()
    whole = detect_offline(log, tags, stacks, 2.0, backend="numpy")
    part = detect_offline(log, tags, stacks, 2.0, backend="numpy",
                          chunk_events=37)
    np.testing.assert_array_equal(part.per_worker, whole.per_worker)
    assert part.total_critical == whole.total_critical
    assert part.total_slices == whole.total_slices


def test_empty_and_trivial_chunks():
    carry = FoldCarry.init(3)
    empty = EventLog(np.zeros(0, np.int64), np.zeros(0, np.int32),
                     np.zeros(0, np.int8), np.zeros(0, np.int32),
                     np.zeros(0, np.int32), 3)
    carry, tbl = fold_chunk(carry, empty, backend="numpy")
    assert len(tbl) == 0 and carry.events == 0
    # a single ACTIVATE: opens a slice, emits nothing
    one = EventLog(np.asarray([5], np.int64), np.asarray([1], np.int32),
                   np.asarray([1], np.int8), np.asarray([-1], np.int32),
                   np.asarray([-1], np.int32), 3)
    carry, tbl = fold_chunk(carry, one, backend="numpy")
    assert len(tbl) == 0
    assert carry.open[1] and carry.thread_count == 1
