"""End-to-end behaviour: train loop (loss drops, profile produced, resume
from checkpoint), serving engine, roofline HLO accounting."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def _trainer(tmp, steps=8, **kw):
    cfg = configs.get_tiny("deepseek-7b")
    opt_cfg = adamw.AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=steps)
    tcfg = TrainerConfig(steps=steps, batch_per_host=4, seq_len=32,
                         ckpt_dir=str(tmp), ckpt_every=4, log_every=100,
                         **kw)
    return Trainer(cfg, opt_cfg, tcfg)


def test_train_e2e_loss_drops_and_profiles(tmp_path):
    tr = _trainer(tmp_path, steps=10)
    tr.run()
    losses = [h["loss"] for h in tr.history]
    assert len(losses) == 10
    assert losses[-1] < losses[0]
    rep = tr.profile_report()
    assert rep.total_slices > 0
    assert "trainer" in rep.worker_names and "data_loader" in rep.worker_names
    # checkpoints were written
    from repro.ckpt import checkpoint
    assert checkpoint.latest_step(str(tmp_path)) == 10


def test_train_resume_from_checkpoint(tmp_path):
    tr = _trainer(tmp_path, steps=4)
    tr.run()
    from repro.ckpt import checkpoint
    assert checkpoint.latest_step(str(tmp_path)) == 4
    tr2 = _trainer(tmp_path, steps=6)
    params, opt, step = tr2.restore_or_init()
    assert step == 4
    tr2.loader.stop()
    tr.loader.stop()
    # restored tree matches saved tree
    saved = checkpoint.restore(str(tmp_path), 4,
                               {"params": params, "opt": opt})
    for a, b in zip(jax.tree.leaves(saved["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_slow_loader_detected(tmp_path):
    tr = _trainer(tmp_path, steps=6, loader_delay_s=0.05)
    tr.run()
    rep = tr.profile_report()
    names = [rep.path_str(p) for p in rep.paths[:3]]
    assert any("wait_data" in n or "data/generate" in n for n in names), names


def test_serve_engine_e2e():
    from repro.models import init_lm
    from repro.serve.engine import Engine, Request
    cfg = configs.get_tiny("gemma3-1b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, batch_slots=4, cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=3),
                    max_new=5 + i) for i in range(6)]
    done = engine.run(reqs)
    assert len(done) == 6
    assert all(len(r.out) == 5 + r.rid for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)


def test_roofline_collective_parsing():
    from repro.launch import roofline
    hlo = """
  %all-gather = bf16[64,1024]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={0}
  %all-reduce.1 = f32[128]{0} all-reduce(%x), replica_groups=[1,256]<=[256], to_apply=%add
  %fusion = f32[2,2] fusion(%all-reduce.1)
  %collective-permute = bf16[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  ROOT %t = (f32[4]{0}, f32[4]{0}) all-to-all(%a, %b), replica_groups=[64,4]<=[256]
"""
    out = roofline.collective_bytes(hlo)
    ag = 64 * 1024 * 2 * (15 / 16)
    ar = 128 * 4 * 2 * (255 / 256)
    cp = 8 * 8 * 2 * 1.0
    a2a = 2 * 4 * 4 * (3 / 4)
    assert out["all-gather"] == pytest.approx(ag)
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["collective-permute"] == pytest.approx(cp)
    assert out["all-to-all"] == pytest.approx(a2a)
    assert out["total"] == pytest.approx(ag + ar + cp + a2a)


def test_roofline_terms_and_bottleneck():
    from repro.launch.roofline import Roofline
    r = Roofline(arch="x", shape="train_4k", mesh="single",
                 flops_per_chip=1.97e14, bytes_per_chip=819e9 * 2,
                 coll_bytes_per_chip=50e9 * 0.5, coll_breakdown={},
                 t_compute=1.0, t_memory=2.0, t_collective=0.5,
                 model_flops=1.97e14 * 256 * 0.7, peak_mem_bytes=8e9,
                 n_chips=256)
    assert r.bottleneck == "memory"
    assert r.t_bound == 2.0
    assert r.useful_ratio == pytest.approx(0.7)
    assert r.roofline_fraction == pytest.approx(0.35)


def test_rules_and_specs_cover_all_cells():
    """Every (arch × shape) cell produces well-formed specs (no compile)."""
    from repro.launch import specs as specs_lib
    from repro.launch.dryrun import rules_for
    for arch, shape_name in configs.grid():
        cfg = configs.get_config(arch)
        shape = configs.SHAPES[shape_name]
        rules = rules_for(arch, shape.kind)
        assert rules.table["cache_seq"] == ("model" if shape.kind == "decode"
                                            else None)
        if shape.kind in ("train", "prefill"):
            sp = specs_lib.train_like_specs(cfg, shape)
            assert sp["tokens"].shape[0] == shape.global_batch
        else:
            tok, pos, state, mem = specs_lib.decode_state_specs(cfg, shape)
            assert tok.shape == (shape.global_batch,)
            assert len(jax.tree.leaves(state)) > 0
