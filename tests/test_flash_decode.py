"""Sequence-sharded flash-decode vs dense reference (subprocess, 4 devices)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.serve.decode_sharded import make_flash_decode
from repro.models.common import ModelConfig

from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("model",))
cfg = ModelConfig(num_heads=8, num_kv_heads=2, head_dim=16)
B, L, H, KV, hd = 3, 64, 8, 2, 16
key = jax.random.PRNGKey(0)
q = jax.random.normal(key, (B, 1, H, hd), jnp.float32)
k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, KV, hd), jnp.float32)
v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, KV, hd), jnp.float32)
valid = jnp.arange(L)[None, :] <= jnp.asarray([10, 40, 63])[:, None]

f = make_flash_decode(mesh, cfg)
out = f(q, k, v, valid)

# dense reference
qg = q.reshape(B, KV, H // KV, hd) * hd ** -0.5
s = jnp.einsum("bkgh,bskh->bkgs", qg, k)
s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
p = jax.nn.softmax(s, axis=-1)
ref = jnp.einsum("bkgs,bskh->bkgh", p, v).reshape(B, 1, H, hd)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("FLASH_DECODE OK")
"""


def test_flash_decode_matches_dense():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "FLASH_DECODE OK" in r.stdout
