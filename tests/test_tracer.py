"""Tracer online state == offline recompute; span/ingest semantics."""
import numpy as np
import pytest

from repro.core import ACTIVATE, DEACTIVATE, Gapp, Tracer, compute_numpy


class FakeClock:
    """Deterministic ns clock for tracer tests."""

    def __init__(self):
        self.t = 0

    def advance(self, ns):
        self.t += int(ns)

    def __call__(self):
        return self.t


def test_online_matches_offline():
    clk = FakeClock()
    tr = Tracer(n_min=1.5, clock=clk)
    w = [tr.register_worker(f"w{i}") for i in range(3)]
    # deterministic schedule
    for rep in range(5):
        for wid in w:
            tr.begin(wid, "work")
            clk.advance(1000)
        for wid in w:
            tr.end(wid)
            clk.advance(500)
        tr.begin(w[0], "solo")
        clk.advance(3000)
        tr.end(w[0])
    log = tr.freeze()
    log.validate()
    res = compute_numpy(log)
    np.testing.assert_allclose(res.per_worker, tr.per_worker_cm(), rtol=1e-9)
    assert res.idle_time == pytest.approx(tr.idle_time)
    # online critical slices == offline threshold application
    offline_crit = int(np.sum(res.critical_mask(1.5)))
    assert offline_crit == len(tr.critical)


def test_critical_capture_only_when_low_parallelism():
    clk = FakeClock()
    tr = Tracer(n_min=2, clock=clk)
    a = tr.register_worker("a")
    b = tr.register_worker("b")
    tr.begin(a, "par")
    tr.begin(b, "par")
    clk.advance(10_000)
    tr.end(a)
    tr.end(b)          # parallel work: threads_av == 2 -> not critical
    tr.begin(a, "serial")
    clk.advance(10_000)
    tr.end(a)          # alone -> critical
    assert len(tr.critical) == 1
    path = tr.stacks.paths[tr.critical[0].stack_id]
    assert tr.tags.names[path[-1]] == "serial"


def test_nested_frames_in_call_path():
    clk = FakeClock()
    tr = Tracer(n_min=10, clock=clk)
    w = tr.register_worker("w")
    tr.begin(w, "train_step")
    with tr.frame(w, "layer_3"):
        with tr.frame(w, "moe_dispatch"):
            clk.advance(1000)
    clk.advance(10)
    tr.end(w)
    # the DEACTIVATE recorded the stack as it was at end: only train_step
    # remains after frames popped; push/pop refine *during* the span, so the
    # critical path uses the stack captured at end()
    assert len(tr.critical) == 1
    # now capture with frames still open
    tr.begin(w, "train_step")
    tr.push(w, "layer_4")
    clk.advance(1000)
    tr.end(w)          # layer_4 on stack at capture
    names = [tr.tags.names[t] for t in
             tr.stacks.paths[tr.critical[-1].stack_id]]
    assert names == ["train_step", "layer_4"]


def test_ingest_external_trace():
    tr = Tracer(n_min=2)
    w = [tr.register_worker(f"h{i}", "host") for i in range(4)]
    # host 2 is a straggler: 3x longer steps
    t = 0
    for step in range(10):
        for h in w:
            tr.ingest(t, h, ACTIVATE, "step")
        t += 1_000_000
        for h in w[:3] + []:
            pass
        for h in (0, 1, 3):
            tr.ingest(t, w[h], DEACTIVATE)
        t += 2_000_000
        tr.ingest(t, w[2], DEACTIVATE)
    cm = tr.per_worker_cm()
    assert cm.argmax() == 2
    assert cm[2] > 2 * cm[0]


def test_ring_overflow_counted():
    # autoflush=False: a full shard drops new events (counted, BPF ringbuf
    # semantics) instead of draining itself through the fold
    tr = Tracer(capacity=8, autoflush=False)
    w = tr.register_worker("w")
    for i in range(10):
        tr.begin(w, "x")
        tr.end(w)
    assert tr.ring.dropped == 12
    assert tr.ring.dropped_per_shard() == [12]
    # the surviving prefix still freezes to a valid log
    log = tr.freeze()
    assert len(log) == 8
    log.validate()


def test_autoflush_drains_instead_of_dropping():
    clk = FakeClock()
    tr = Tracer(n_min=0.0, capacity=8, clock=clk)
    w = tr.register_worker("w")
    for i in range(50):
        tr.begin(w, "x")
        clk.advance(1000)
        tr.end(w)
        clk.advance(100)
    assert tr.ring.dropped == 0
    log = tr.freeze()
    assert len(log) == 100
    log.validate()
    res = compute_numpy(log)
    np.testing.assert_array_equal(res.per_worker, tr.per_worker_cm())


def test_gapp_facade_live(tmp_path):
    import time
    g = Gapp(n_min=None, dt=0.001)
    ws = [g.register_worker(f"t{i}") for i in range(4)]
    with g.running():
        for _ in range(3):
            for w in ws[:3]:
                g.begin(w, "parallel")
            time.sleep(0.003)
            for w in ws[:3]:
                g.end(w)
            g.begin(ws[3], "bottleneck")
            time.sleep(0.006)
            g.end(ws[3])
    rep = g.report()
    assert rep.paths, "no critical paths found"
    assert "bottleneck" in rep.path_str(rep.paths[0])
    assert rep.per_worker.argmax() == 3
    # offline recompute from the ring agrees
    log = g.freeze()
    res = compute_numpy(log)
    np.testing.assert_allclose(res.per_worker, rep.per_worker, rtol=1e-6)
