"""SpillStore journal rotation + retention.

Invariants under test: block indices are GLOBAL across rotated segments
(block index == append order == chunk seq forever), readers span sealed
segments + the active file transparently, and retention never prunes a
block above the ack floor — a rotated capture replays bit-equal to an
unrotated one.
"""
import os

import numpy as np

from repro.core import SpillStore


def _block(t0, n=10):
    times = np.arange(t0, t0 + n, dtype=np.int64)
    workers = np.zeros(n, np.int32)
    deltas = np.ones(n, np.int8)
    tags = np.zeros(n, np.int32)
    stacks = np.full(n, -1, np.int32)
    return times, workers, deltas, tags, stacks


def _append_blocks(st, count, start=0, n=10):
    idxs = []
    for i in range(count):
        idxs.append(st.append_block(*_block((start + i) * 1000, n)))
    return idxs


def test_rotation_rolls_segments_and_keeps_global_indices(tmp_path):
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_bytes=1)   # roll after every block
    idxs = _append_blocks(st, 10)
    assert idxs == list(range(10))          # global, monotonic
    assert st.blocks == 10
    assert st.segments >= 3
    st.close()
    segs = [f for f in os.listdir(tmp_path) if f.endswith(".seg")]
    assert len(segs) >= 3


def test_reader_spans_segments_bit_equal(tmp_path):
    plain = str(tmp_path / "plain.spill")
    rotated = str(tmp_path / "rot.spill")
    a, b = SpillStore(plain), SpillStore(rotated, rotate_bytes=1)
    for st in (a, b):
        _append_blocks(st, 8)
        st.close()
    la = SpillStore.open_readonly(plain).freeze(1)
    lb = SpillStore.open_readonly(rotated).freeze(1)
    np.testing.assert_array_equal(la.times, lb.times)
    np.testing.assert_array_equal(la.workers, lb.workers)
    np.testing.assert_array_equal(la.deltas, lb.deltas)


def test_iter_blocks_skip_is_global(tmp_path):
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_bytes=1)
    _append_blocks(st, 10)
    st.close()
    ro = SpillStore.open_readonly(path)
    got = list(ro.iter_block_columns(skip=7))
    assert len(got) == 3
    assert got[0][0][0] == 7000     # first time of block 7


def test_open_append_resumes_global_numbering(tmp_path):
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_bytes=1)
    _append_blocks(st, 5)
    st.close()
    st = SpillStore.open_append(path, rotate_bytes=1)
    assert st.blocks == 5
    assert st.append_block(*_block(5000)) == 5
    st.close()
    ro = SpillStore.open_readonly(path)
    assert ro.blocks == 6


def test_retention_never_prunes_unacked(tmp_path):
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_bytes=1, retain_blocks=1)
    _append_blocks(st, 10)
    # no ack floor yet: retention must hold EVERY block
    assert st.first_block == 0
    assert list(st.iter_block_columns())    # all readable
    st.set_ack_floor(10)
    assert st.first_block >= 8              # now pruning may proceed
    assert st.blocks == 10                  # indices still global
    st.close()


def test_ack_floor_prunes_whole_segments_only(tmp_path):
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_bytes=1)   # retain_blocks=None: keep all
    _append_blocks(st, 10)
    st.set_ack_floor(7)
    assert st.first_block == 0              # no retention policy: no prune
    st.close()
    st = SpillStore.open_append(path, rotate_bytes=1, retain_blocks=2)
    st.set_ack_floor(8)
    assert 0 < st.first_block <= 8          # pruned leading segments, never
    #                                         past min(ack, blocks - retain)
    # the retained tail is still readable from its global offset
    kept = list(st.iter_block_columns(skip=st.first_block))
    assert kept[0][0][0] == st.first_block * 1000
    st.close()


def test_replay_tail_after_prune_matches(tmp_path):
    """The fleet-replay contract: after pruning below the ack floor, every
    block >= floor replays exactly (the unacked tail a reconnect needs)."""
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_bytes=1, retain_blocks=3)
    _append_blocks(st, 12)
    st.set_ack_floor(9)
    for i, cols in enumerate(st.iter_block_columns(skip=9)):
        assert cols[0][0] == (9 + i) * 1000
    st.close()


def test_rotate_age_seals_old_segment(tmp_path):
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_age_s=0.0)     # every append is "old"
    _append_blocks(st, 3)
    assert st.segments >= 2
    assert st.blocks == 3
    st.close()
    ro = SpillStore.open_readonly(path)
    assert ro.blocks == 3
    assert [c[0][0] for c in ro.iter_block_columns()] == [0, 1000, 2000]


def test_unrotated_store_unchanged(tmp_path):
    """Default path: no rotation kwargs → single file, no .seg clutter."""
    path = str(tmp_path / "j.spill")
    st = SpillStore(path)
    _append_blocks(st, 6)
    assert st.segments == 0
    st.close()
    assert [f for f in os.listdir(tmp_path)] == ["j.spill"]


# ---------------------------------------------------------------------------
# capture-time block index: age retention + windowed reads (ISSUE 9)
# ---------------------------------------------------------------------------

def test_prune_before_time_respects_ack_floor(tmp_path):
    """Age-based retention NEVER drops an unacked block when asked to
    respect the ack floor — a replay consumer outranks any age budget."""
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_bytes=1)
    _append_blocks(st, 10)                  # block i covers [i*1000, ...]
    # nothing acked: a cutoff past ALL data must prune nothing
    assert st.prune_before_time(10 ** 9) == 0
    assert st.first_block == 0
    st.set_ack_floor(6)
    # cutoff at t=5000 -> horizon block 5, capped by ack floor 6 -> 5
    assert st.prune_before_time(5000) == 5
    assert st.first_block == 5
    assert st.pruned_blocks == 5
    # without respect_ack (server-side journals: the server IS the
    # consumer) the same cutoff prunes up to the time horizon alone
    assert st.prune_before_time(8000, respect_ack=False) == 3
    assert st.first_block == 8
    # the newest block always survives: its bound >= any past cutoff
    assert st.time_bounds() is not None
    st.close()


def test_windowed_read_rotated_bit_equal_unrotated(tmp_path):
    """The acceptance property behind /api/top?window=: a windowed block
    read over a rotated multi-segment journal yields bit-equal columns to
    the same window over an unrotated journal."""
    plain = str(tmp_path / "plain.spill")
    rotated = str(tmp_path / "rot.spill")
    a, b = SpillStore(plain), SpillStore(rotated, rotate_bytes=1)
    for st in (a, b):
        _append_blocks(st, 12)
    lo, hi = 2500, 8200                     # blocks 3..8 intersect
    wa = list(a.iter_block_columns_window(lo, hi))
    wb = list(b.iter_block_columns_window(lo, hi))
    assert len(wa) == len(wb) == 6
    assert wa[0][0][0] == 3000 and wa[-1][0][0] == 8000
    for ca, cb in zip(wa, wb):
        for x, y in zip(ca, cb):
            np.testing.assert_array_equal(x, y)
    # and both agree after sealing + reopening read-only
    a.close(), b.close()
    wr = list(SpillStore.open_readonly(rotated)
              .iter_block_columns_window(lo, hi))
    for ca, cr in zip(wa, wr):
        np.testing.assert_array_equal(ca[0], cr[0])


def test_windowed_read_exact_after_prune_and_reopen(tmp_path):
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_bytes=1)
    _append_blocks(st, 10)
    st.prune_before_time(4000, respect_ack=False)
    st.close()
    ro = SpillStore.open_readonly(path)
    # the index rebuilt from surviving segments still maps global time
    assert ro.time_bounds() == (4000, 9009)
    got = [c[0][0] for c in ro.iter_block_columns_window(5000, 7000)]
    assert got == [5000, 6000, 7000]
    # a window entirely inside the pruned region yields nothing
    assert list(ro.iter_block_columns_window(0, 3000)) == []


def test_time_bounds_and_index_survive_reopen(tmp_path):
    path = str(tmp_path / "j.spill")
    st = SpillStore(path, rotate_bytes=1)
    _append_blocks(st, 4)
    # an empty block (seq filler) must not poison the bounds
    st.append_block(*_block(0, n=0))
    _append_blocks(st, 1, start=9)
    assert st.time_bounds() == (0, 9009)
    st.close()
    ro = SpillStore.open_readonly(path)
    assert ro.time_bounds() == (0, 9009)
    # the filler block is yielded inside the contiguous range (callers
    # row-trim, and an empty block trims to nothing) — never tripped over
    got = [c[0][0] for c in ro.iter_block_columns_window(3000, 9500)
           if c[0].size]
    assert got == [3000, 9000]
