"""Per-arch smoke tests (reduced configs) + layer-level correctness oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (cross_memory, decode_step, forward,
                          init_decode_state, init_lm)
from repro.models.common import ModelConfig

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, s=S):
    b = {"tokens": jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)}
    if cfg.enc_layers:
        b["frontend"] = jax.random.normal(KEY, (B, 12, cfg.frontend_dim))
    elif cfg.frontend_dim:
        b["frontend"] = jax.random.normal(KEY, (B, cfg.num_prefix,
                                                cfg.frontend_dim))
    return b


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: shapes + finiteness."""
    cfg = configs.get_tiny(arch)
    params = init_lm(KEY, cfg)
    batch = _batch(cfg)
    logits, _ = forward(params, batch, cfg)
    exp_s = batch["tokens"].shape[1] + (
        cfg.num_prefix if cfg.frontend_dim and not cfg.enc_layers else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    from repro.optim import adamw
    from repro.train.step import make_train_step
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(lr=1e-3)),
                   donate_argnums=(0, 1))
    opt = adamw.init(params)
    p2, o2, m, _ = step(params, opt, batch, None)
    assert np.isfinite(float(m["loss"]))
    assert int(o2["step"]) == 1


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_decode_matches_forward(arch):
    """Teacher-forced decode over the same tokens reproduces the forward
    logits (per-position) — validates every cache/state implementation."""
    import dataclasses
    cfg = configs.get_tiny(arch)
    if cfg.frontend_dim and not cfg.enc_layers:
        pytest.skip("vlm prefix handled in test below")
    if cfg.num_experts:
        # capacity-based routing drops tokens differently at S=8 vs S=1;
        # equivalence holds in the drop-free regime
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_lm(KEY, cfg)
    batch = _batch(cfg, s=8)
    tokens = batch["tokens"]
    logits_full, _ = forward(params, batch, cfg)
    state = init_decode_state(cfg, B, 8)
    mem = cross_memory(params, cfg, batch["frontend"]) if cfg.enc_layers \
        else None
    outs = []
    for t in range(tokens.shape[1]):
        lg, state = decode_step(params, tokens[:, t],
                                jnp.full((B,), t, jnp.int32), state, cfg,
                                memory=mem)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=0.15, atol=0.15)


def test_scan_layers_equals_unrolled():
    # f32 compute isolates structure from bf16 accumulation-order noise
    cfg = configs.get_tiny("deepseek-7b")
    cfg = ModelConfig(**{**cfg.__dict__, "num_layers": 4,
                         "compute_dtype": jnp.float32})
    params = init_lm(KEY, cfg)
    batch = _batch(cfg)
    a, _ = forward(params, batch, cfg, scan_layers=False)
    b, _ = forward(params, batch, cfg, scan_layers=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_chunked_local_equals_masked():
    cfg = configs.get_tiny("gemma3-1b")
    params = init_lm(KEY, cfg)
    batch = _batch(cfg, s=32)   # window 8, 4 chunks
    a, _ = forward(params, batch, cfg, local_impl="mask")
    b, _ = forward(params, batch, cfg, local_impl="chunked")
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_rwkv_chunked_vs_sequential():
    """The chunked RWKV-6 time mix must equal the token-by-token recurrence."""
    from repro.models import recurrent as rec
    cfg = configs.get_tiny("rwkv6-1.6b")
    p = rec.init_rwkv_tmix(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, 24, cfg.d_model),
                          cfg.compute_dtype) * 0.5
    y_chunk, st_chunk = rec.rwkv_tmix(p, x, cfg)          # chunk_size=8
    st = rec.init_rwkv_state(cfg, B)
    ys = []
    for t in range(x.shape[1]):
        y, st = rec.rwkv_tmix_step(p, x[:, t:t + 1], st, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(st_chunk["s"]),
                               np.asarray(st["s"]), rtol=3e-2, atol=3e-2)


def test_rglru_block_vs_step():
    from repro.models import recurrent as rec
    cfg = configs.get_tiny("recurrentgemma-2b")
    p = rec.init_rglru(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, 12, cfg.d_model),
                          cfg.compute_dtype) * 0.5
    y_full, h_last = rec.rglru_block(p, x, cfg)
    st = rec.init_rglru_state(cfg, B)
    ys = []
    for t in range(x.shape[1]):
        y, st = rec.rglru_step(p, x[:, t:t + 1], st, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(st["h"]),
                               rtol=2e-2, atol=2e-2)


def test_moe_routing_properties():
    from repro.models import moe as moe_lib
    cfg = configs.get_tiny("arctic-480b")
    p = moe_lib.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, 32, cfg.d_model),
                          cfg.compute_dtype)
    y, aux = moe_lib.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    # every token routes top_k times (minus drops)
    total = int(jnp.sum(aux["expert_load"]))
    assert total == B * 32 * cfg.top_k
    assert float(aux["aux_loss"]) > 0


def test_moe_capacity_drops():
    from repro.models import moe as moe_lib
    cfg = configs.get_tiny("arctic-480b")
    cfg = ModelConfig(**{**cfg.__dict__, "capacity_factor": 0.1})
    p = moe_lib.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, 64, cfg.d_model),
                          cfg.compute_dtype)
    y, aux = moe_lib.moe_ffn(p, x, cfg)
    assert int(aux["dropped"]) > 0
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_exact_param_counts_vs_analytic():
    """Analytic param_count (used for 6ND roofline) within 2% of actual."""
    for arch in configs.ARCHS:
        cfg = configs.get_tiny(arch)
        params = init_lm(KEY, cfg)
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.25, (arch, est, actual)


def test_blockwise_attention_equals_full():
    import dataclasses
    cfg = configs.get_tiny("deepseek-7b")
    cfg_b = dataclasses.replace(cfg, attn_qchunk=8)
    params = init_lm(KEY, cfg)
    batch = _batch(cfg, s=32)
    a, _ = forward(params, batch, cfg)
    b, _ = forward(params, batch, cfg_b)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_rwkv_opt_level_same_result():
    import dataclasses
    cfg = configs.get_tiny("rwkv6-1.6b")
    cfg_o = dataclasses.replace(cfg, opt_level=1)
    params = init_lm(KEY, cfg)
    batch = _batch(cfg)
    a, _ = forward(params, batch, cfg)
    b, _ = forward(params, batch, cfg_o)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-5,
                               atol=1e-5)
