"""Known-bad publication fixture: a row field written after publication.

The metas append is the publication point; the times append lands after
it, so a reader that observes the meta can see a torn row.
"""
from collections import deque


class TornShard:
    def __init__(self):
        self.times = deque()
        self.metas = deque()

    def append(self, t, meta):
        self.metas.append(meta)   # publishes: self.times
        self.times.append(t)      # BAD: late write
