"""Known-good guarded-by fixture: every mutation path holds the lock."""
import threading


class GoodCounter:
    def __init__(self):
        self.lock = threading.Lock()
        self.ready = threading.Condition(self.lock)  # alias of self.lock
        self.value = 0       # guarded-by: self.lock
        self.items = []      # guarded-by: self.lock

    def bump(self):
        with self.lock:
            self.value += 1

    def bump_via_condition(self):
        # Acquiring the Condition IS acquiring the aliased lock.
        with self.ready:
            self.items.append(self.value)
            self.ready.notify()

    def _bump_locked(self):  # guarded-by: self.lock
        self.value += 1
        self.items.clear()

    def outer(self):
        with self.lock:
            self._bump_locked()


def external(counter):
    with counter.lock:
        counter.value = 5
