"""Acyclic lock order — the leaf-stats-lock shape PR 4 actually shipped.

The registry lock may nest a host lock, and the host lock may nest the
stats lock, but the stats lock is a leaf: nothing is ever acquired under
it, so the order graph is a straight chain.
"""
import threading


class LeafLockServer:
    def __init__(self):
        self._registry_lock = threading.Lock()
        self._host_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.hosts = {}
        self.stats = 0

    def register(self, name):
        with self._registry_lock:
            with self._host_lock:
                self.hosts[name] = object()

    def on_chunk(self, name):
        with self._host_lock:
            self._bump_stats()

    def _bump_stats(self):
        with self._stats_lock:
            self.stats += 1

    def report(self):
        with self._stats_lock:
            return self.stats
