"""Known-bad event-loop fixture: blocking calls inside selector callbacks.

``_loop`` is the annotated root; ``_on_ready`` is a selector callback and
sleeps, and the compaction helper it calls fsyncs — both reachable from
the loop, both findings.  ``close`` also sleeps but is *not* reachable
from the root, so it must not be flagged.
"""
import os
import selectors
import time


class SleepyLoop:
    def __init__(self):
        self._sel = selectors.DefaultSelector()
        self.fd = 0

    def _loop(self):  # lint: event-loop
        while True:
            for _key, _events in self._sel.select(0.05):
                self._on_ready(_key)

    def _on_ready(self, key):
        time.sleep(0.1)          # BAD: stalls every connected host
        self._compact()

    def _compact(self):
        os.fsync(self.fd)        # BAD: disk barrier on the loop thread

    def close(self):
        time.sleep(0.2)          # fine: not reachable from _loop
