"""Known-bad guarded-by fixture: mutations outside the declared lock."""
import threading


class Counter:
    def __init__(self):
        self.lock = threading.Lock()
        self.value = 0       # guarded-by: self.lock
        self.items = []      # guarded-by: self.lock

    def good_bump(self):
        with self.lock:
            self.value += 1

    def bad_bump(self):
        self.value += 1      # BAD: no lock held

    def bad_append(self):
        self.items.append(1)  # BAD: container mutator outside the lock

    def _locked_bump(self):  # guarded-by: self.lock
        self.value += 1

    def bad_contract_call(self):
        self._locked_bump()  # BAD: contract method without the lock


def bad_external(counter):
    # Unique-owner resolution: `value` is guarded only by Counter, so a
    # foreign-receiver mutation needs `counter.lock`.
    counter.value = 5        # BAD
