"""Known-good event-loop fixture: callbacks stay non-blocking."""
import selectors


class PromptLoop:
    def __init__(self):
        self._sel = selectors.DefaultSelector()
        self.pending = []

    def _loop(self):  # lint: event-loop
        while True:
            for _key, _events in self._sel.select(0.05):
                self._on_ready(_key)

    def _on_ready(self, key):
        sock = key.fileobj
        data = sock.recv(4096)   # non-blocking socket: fine
        if data:
            self.pending.append(data)

    def close(self):
        # Blocking is fine OFF the loop thread.
        import time
        time.sleep(0.2)
