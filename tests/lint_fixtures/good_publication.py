"""Known-good publication fixture: all fields written, then published."""
from collections import deque


class OrderedShard:
    def __init__(self):
        self.times = deque()
        self.deltas = deque()
        self.metas = deque()

    def append(self, t, delta, meta):
        self.times.append(t)
        self.deltas.append(delta)
        self.metas.append(meta)   # publishes: self.times, self.deltas
