"""Seeded ABBA cycle — the PR 4 shape the static pass must rediscover.

``register`` takes the registry lock then a host lock; the chunk path
takes the host lock and then (one call deep, so the rule has to be
interprocedural) the registry lock.  Neither path deadlocks alone; run
them on two threads and they deadlock against each other.
"""
import threading


class AbbaServer:
    def __init__(self):
        self._registry_lock = threading.Lock()
        self._host_lock = threading.Lock()
        self.hosts = {}
        self.stats = 0

    def register(self, name):
        with self._registry_lock:          # A ...
            with self._host_lock:          # ... then B
                self.hosts[name] = object()

    def on_chunk(self, name):
        with self._host_lock:              # B ...
            self._note_registry()

    def _note_registry(self):
        with self._registry_lock:          # ... then A  (ABBA!)
            self.stats += 1
