"""CMetric backend registry: dispatch, capabilities, custom registration."""
import numpy as np
import pytest

from repro.core import (available_backends, backends_with, compute,
                        compute_vectorized, get_backend, register_backend,
                        synthetic_log)
from repro.core import backends as backends_lib


def test_registry_has_all_four_backends():
    names = available_backends()
    for b in ("numpy", "stream", "vector", "pallas"):
        assert b in names


def test_unknown_backend_raises_with_available_names():
    with pytest.raises(KeyError, match="numpy"):
        get_backend("no-such-backend")


def test_capability_queries():
    assert "numpy" in backends_with("oracle")
    assert "numpy" not in backends_with("device")
    for b in ("stream", "vector", "pallas"):
        assert b in backends_with("device")
    assert backends_with("fused") == ["pallas"]
    assert "fused" in get_backend("pallas").capabilities


def test_compute_dispatches_through_registry():
    rng = np.random.default_rng(0)
    log = synthetic_log(rng, 4, 10)
    a = compute(log, backend="vector")
    b = compute_vectorized(log)
    np.testing.assert_allclose(a.per_worker, b.per_worker, rtol=1e-9)


def test_register_custom_backend_and_unregister():
    calls = []

    @register_backend("test_probe", capabilities={"test"})
    def probe(log):
        calls.append(len(log))
        return compute(log, backend="numpy")

    try:
        assert "test_probe" in available_backends()
        assert backends_with("test") == ["test_probe"]
        rng = np.random.default_rng(1)
        log = synthetic_log(rng, 3, 5)
        res = compute(log, backend="test_probe")
        assert calls == [len(log)]
        assert res.num_slices == 15
    finally:
        backends_lib.unregister_backend("test_probe")
    assert "test_probe" not in available_backends()
    with pytest.raises(KeyError):
        get_backend("test_probe")


def test_pallas_registration_is_lazy():
    # the registry holds a loader; resolving the name must not import the
    # kernels package as a side effect of registry lookups alone
    b = get_backend("pallas")
    assert b.name == "pallas"
    assert callable(b.fn)
