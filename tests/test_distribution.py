"""Distribution substrate on an 8-placeholder-device mesh (via subprocess
env) is covered by test_dryrun_small.py; here: optimizer, compression,
checkpointing, data pipeline, pipeline parallelism on the host devices."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import checkpoint
from repro.models import init_lm
from repro.optim import adamw, compression


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                            weight_decay=0.0, clip_norm=0)
    params = {"w": jnp.ones((4,)) * 5.0}
    state = adamw.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.update(cfg, grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1.0
    assert int(state["step"]) == 60


def test_grad_clip_and_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            clip_norm=1.0)
    s0 = adamw.schedule(cfg, jnp.asarray(0))
    s5 = adamw.schedule(cfg, jnp.asarray(5))
    s10 = adamw.schedule(cfg, jnp.asarray(10))
    assert float(s0) == 0.0 and float(s5) == pytest.approx(0.5)
    assert float(s10) == pytest.approx(1.0)
    params = {"w": jnp.zeros((3,))}
    state = adamw.init(params)
    _, _, m = adamw.update(cfg, {"w": jnp.ones((3,)) * 100}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(100 * np.sqrt(3), rel=1e-5)


def test_int8_error_feedback_converges():
    """With error feedback, quantised SGD still drives a quadratic to zero."""
    def grad_fn(params, batch):
        return {"w": 2 * params["w"]}, {}
    f = compression.wrap_grad_fn(grad_fn, "int8")
    params = {"w": jnp.ones((8,)) * 3.0}
    err = compression.init_error(params)
    for _ in range(200):
        g, _, err = f(params, None, err)
        params = {"w": params["w"] - 0.05 * g["w"]}
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_topk_compression_sparsity():
    def grad_fn(params, batch):
        return {"w": jnp.arange(100.0)}, {}
    f = compression.wrap_grad_fn(grad_fn, "topk", topk_frac=0.1)
    params = {"w": jnp.zeros(100)}
    g, _, err = f(params, None, compression.init_error(params))
    nz = int(jnp.sum(g["w"] != 0))
    assert nz == 10
    # residual carries the rest
    assert float(jnp.sum(err["w"])) == pytest.approx(
        float(jnp.sum(jnp.arange(100.0))) - float(jnp.sum(g["w"])))


def test_checkpoint_roundtrip(tmp_path):
    cfg = configs.get_tiny("deepseek-7b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    tree = {"params": params, "opt": opt}
    checkpoint.save(str(tmp_path), 7, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    restored = checkpoint.restore(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_prune(tmp_path):
    tree = {"x": jnp.arange(10)}
    for s in (1, 2, 3):
        t = checkpoint.save(str(tmp_path), s, tree, blocking=False)
        t.join()
    checkpoint.prune(str(tmp_path), keep=2)
    assert checkpoint.latest_step(str(tmp_path)) == 3
    assert not os.path.isdir(os.path.join(str(tmp_path), "step_000001"))


def test_checkpoint_elastic_resharding(tmp_path):
    """Save unsharded, restore with explicit shardings (1-device 'mesh')."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    checkpoint.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored = checkpoint.restore(str(tmp_path), 1, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


def test_incomplete_checkpoint_rejected(tmp_path):
    d = tmp_path / "step_000009"
    d.mkdir(parents=True)
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(str(tmp_path), 9, {"x": jnp.zeros(1)})
    assert checkpoint.latest_step(str(tmp_path)) is None


def test_data_pipeline_prefetch_and_profile():
    from repro.core.profiler import Gapp
    from repro.data.pipeline import PrefetchLoader, SyntheticLM
    g = Gapp(n_min=4)
    src = SyntheticLM(vocab_size=100, seq_len=8, batch_per_host=2)
    loader = PrefetchLoader(src, depth=2, gapp=g)
    batches = [loader.get() for _ in range(5)]
    loader.stop()
    assert all(b["tokens"].shape == (2, 8) for b in batches)
    assert all(b["tokens"].min() >= 0 and b["tokens"].max() < 100
               for b in batches)
    # loader spans were recorded
    assert g.tracer.per_worker_cm()[0] > 0


def test_straggler_monitor():
    from repro.ft.monitor import StragglerMonitor
    mon = StragglerMonitor(num_hosts=8, zmax=2.0)
    t = 0
    for step in range(20):
        for h in range(8):
            dur = 3_000_000 if h == 5 else 1_000_000
            mon.record_step(h, t, t + dur)
        t += 4_000_000
    v = mon.verdict()
    assert v.host == 5 and v.is_straggler


def test_run_with_restarts():
    from repro.ft.monitor import run_with_restarts
    calls = []

    def train_fn(start_step):
        calls.append(start_step)
        if len(calls) < 3:
            raise RuntimeError("simulated node failure")
        return 100

    assert run_with_restarts(train_fn, max_restarts=5) == 100
    assert calls == [0, -1, -1]


@pytest.mark.skipif(len(jax.devices()) < 1, reason="needs devices")
def test_gpipe_single_stage_identity():
    from repro.pipeline.gpipe import gpipe
    mesh = jax.make_mesh((1,), ("stage",))
    stage_fn = lambda p, x: x * p["scale"]
    params = {"scale": jnp.ones((1,)) * 2.0}
    f = gpipe(stage_fn, mesh, n_stages=1, n_micro=3)
    x = jnp.arange(12.0).reshape(3, 4)
    y = f(params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2.0)


def test_gpipe_schedule_bubble_fraction():
    from repro.pipeline.gpipe import schedule_intervals
    iv = schedule_intervals(n_stages=4, n_micro=8)
    span = max(e for _, _, e in iv) - min(s for _, s, _ in iv)
    busy = sum(e - s for _, s, e in iv)
    bubble = 1 - busy / (span * 4)
    assert bubble == pytest.approx((4 - 1) / (8 + 4 - 1))
