"""Sharding rules: logical->physical binding, divisibility filtering,
param-tree rule coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_mesh
from repro.models import init_lm
from repro.sharding import api as shapi
from repro.sharding import params as shparams


def _mesh22():
    return make_mesh((1, 1), ("data", "model"))


def test_filter_entry_drops_missing_axes():
    mesh = _mesh22()
    assert shapi.filter_entry(8, ("pod", "data"), mesh) == "data"
    assert shapi.filter_entry(8, "pod", mesh) is None
    assert shapi.filter_entry(8, "model", mesh) == "model"


def test_filter_entry_divisibility():
    mesh = make_mesh((2, 4), ("data", "model")) \
        if len(jax.devices()) >= 8 else None
    if mesh is None:
        pytest.skip("needs 8 devices (covered by subprocess tests)")


def test_rules_spec_and_replace():
    rules = shapi.default_rules(seq="model")
    assert rules.spec("batch", "seq") == P(("pod", "data"), "model")
    rules2 = rules.replace(seq=None)
    assert rules2.spec("batch", "seq") == P(("pod", "data"), None)
    assert rules.spec("batch", "seq") == P(("pod", "data"), "model")


def test_constrain_noop_outside_binding():
    x = jnp.zeros((4, 4))
    y = shapi.constrain(x, "batch", "embed")
    assert y is x


def test_param_rules_cover_every_leaf():
    """Every param leaf in every arch matches a rule or is a norm/scalar
    (replicated by default) — no silent misses on matrices."""
    for arch in configs.ARCHS:
        cfg = configs.get_tiny(arch)
        shapes = jax.eval_shape(lambda c=cfg: init_lm(jax.random.PRNGKey(0),
                                                      c))
        logical = shparams.logical_param_specs(shapes)
        flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
        flat_l = jax.tree.leaves(logical, is_leaf=lambda x: isinstance(
            x, tuple))
        assert len(flat_s) == len(flat_l)
        for (path, leaf), axes in zip(flat_s, flat_l):
            names = shparams._path_names(path)
            # any matrix of rank >=2 that is not a norm/gate should have at
            # least one sharded axis in its logical spec
            big = int(np.prod(leaf.shape)) >= 64 * 64 and len(leaf.shape) >= 2
            if big and all(a is None for a in axes):
                raise AssertionError(
                    f"{arch}: unsharded big leaf {'/'.join(names)} "
                    f"{leaf.shape}")


def test_physical_specs_on_trivial_mesh():
    cfg = configs.get_tiny("deepseek-7b")
    shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    mesh = _mesh22()
    specs = shparams.physical_specs(shapes, mesh, shapi.default_rules())
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)


def test_constrain_inside_binding_applies():
    mesh = _mesh22()
    with shapi.use_mesh(mesh, shapi.default_rules()):
        assert shapi.axis_size("heads") == 1

        @jax.jit
        def f(x):
            return shapi.constrain(x, "batch", "mlp")
        y = f(jnp.zeros((4, 8)))
        assert y.shape == (4, 8)
