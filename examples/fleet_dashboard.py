"""Live dashboard quickstart: continuous profiling over HTTP.

Same two-host fleet as ``fleet_profile.py`` — one ingest server, two
producer "hosts", one of which serializes on a shared lock — but instead
of a one-shot text report the fleet session *serves* its state live:

    service = fleet.serve()         # ProfilerService on 127.0.0.1:<port>

While the workload streams in, the script queries the running service
the way a dashboard or ``curl`` would:

* ``GET /``                 no-dependency HTML dashboard (open in a browser);
* ``GET /api/report``       the full report, byte-equal to ``export("json")``;
* ``GET /api/top?n=3&window=0.5``  top bottlenecks over the last 0.5 s,
  re-folded incrementally from the durable fleet_dir journals;
* ``GET /api/hosts``        per-host drill-down + transport health;
* ``GET /metrics``          Prometheus text exposition for scraping.

Run:  PYTHONPATH=src python examples/fleet_dashboard.py
"""
import json
import tempfile
import threading
import time
import urllib.request

from repro.core import ProfileSession
from repro.fleet import IngestServer, attach_remote


def run_host(host_id: str, server_addr, serial: bool) -> None:
    s = ProfileSession(n_min=None, dt=0.001)
    lock = threading.Lock()
    wids = [s.register_worker(f"worker{i}") for i in range(4)]
    sink = attach_remote(s, server_addr, host_id=host_id, clock_offset_ns=0)

    def worker(i):
        for _ in range(8):
            with s.span(wids[i], "parallel_compute"):
                time.sleep(0.003)
            if serial and i == 0:
                with s.span(wids[i], "commit_txn"):
                    with lock:
                        time.sleep(0.010)

    with s.running():
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    s.result()
    sink.close()


def get(addr, path):
    with urllib.request.urlopen(
            f"http://{addr[0]}:{addr[1]}{path}", timeout=5) as r:
        return r.read()


def main():
    fleet_dir = tempfile.mkdtemp(prefix="gapp-dash-")
    server = IngestServer(fleet_dir=fleet_dir)   # durable journals
    server.start()
    fleet = ProfileSession(server.source, n_min=2.0)
    fleet.start()
    service = fleet.serve(server=server)         # HTTP API, ephemeral port
    addr = service.address
    print(f"dashboard:  http://{addr[0]}:{addr[1]}/")
    print(f"fleet_dir:  {fleet_dir}\n")

    hosts = [threading.Thread(target=run_host,
                              args=(name, server.address, name == "db-1"))
             for name in ("web-0", "db-1")]
    for t in hosts:
        t.start()
    for t in hosts:
        t.join()
    assert server.wait_idle(10.0), server.stats()

    # -- query the LIVE service, as a dashboard would -------------------
    report = json.loads(get(addr, "/api/report"))
    assert report == json.loads(fleet.export("json"))
    print(f"live report: {report['total_slices']} slices, "
          f"critical_ratio={report['critical_ratio']:.2f}, "
          f"hosts={sorted(report['per_host'])}")

    top = json.loads(get(addr, "/api/top?n=3&window=0.5"))
    print("top bottlenecks (last 0.5 s of fleet time):")
    for e in top["entries"]:
        print(f"  {e['path']:40s} cmetric={e['cmetric_s']:.4f}s "
              f"slices={e['slices']}")
    assert any("commit_txn" in e["path"] for e in top["entries"])

    drill = json.loads(get(addr, "/api/hosts/db-1"))
    print(f"db-1 drill-down: {drill['workers']} workers, "
          f"journal blocks={drill['journal']['blocks']}")

    metrics = get(addr, "/metrics").decode()
    line = next(ln for ln in metrics.splitlines()
                if ln.startswith("gapp_session_events_folded"))
    print(f"prometheus:  {line}")

    service.close()
    fleet.stop()
    server.close()


if __name__ == "__main__":
    main()
