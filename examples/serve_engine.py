"""Batched serving with the decode engine + GAPP request profiling.

Each batch slot is a logical worker.  A mixed workload (many short
requests, a few very long ones) exhibits the classic continuous-batching
pathology: near the tail, most slots sit idle while the long requests hold
the batch — reduced parallelism, high CMetric for the long-request spans.

Run:  PYTHONPATH=src python examples/serve_engine.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.core import ProfileSession
from repro.models import init_lm
from repro.serve.engine import Engine, Request


def main():
    cfg = configs.get_tiny("deepseek-7b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    gapp = ProfileSession(n_min=None, dt=0.002)
    engine = Engine(cfg, params, batch_slots=8, cache_len=128, gapp=gapp)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(16):
        long = i in (3, 7)
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, size=4),
            max_new=192 if long else 12))

    # warm up the compiled decode step so compile time doesn't pollute spans
    engine._step(params, engine.tokens, engine.pos, engine.state)

    t0 = time.perf_counter()
    with gapp.running():
        finished = engine.run(reqs)
    wall = time.perf_counter() - t0

    rep = gapp.result()
    print(gapp.export("text", max_paths=4))
    toks = sum(len(r.out) for r in finished)
    print(f"served {len(finished)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.0f} tok/s)")
    top = rep.path_str(rep.paths[0]) if rep.paths else "?"
    print(f"top critical path: {top}")
    assert "req3" in top or "req7" in top, top
    print("=> the long requests (3 and 7) serialized the batch tail — "
          "exactly what the CMetric ranks first. A scheduler fix "
          "(length-aware admission) is the 'fix the bottleneck' step.")
    # causal what-if: what is that fix worth?  Replay the capture with
    # the top path's critical slices removed — no re-run needed.
    wi = rep.what_if(path=1, shrink=0.0)
    print(f"what-if: fixing '{wi.selection['value']}' is worth "
          f"{wi.speedup:.2f}x end-to-end "
          f"(saves {wi.saved_s * 1e3:.1f} ms of {wall * 1e3:.0f} ms)")


if __name__ == "__main__":
    main()
