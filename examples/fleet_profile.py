"""Fleet profiling quickstart: one ingest server + two hosts on localhost.

Two "hosts" (processes in real deployments; sessions here) run the same
4-worker workload, but on host ``db-1`` one worker also serializes on a
shared lock.  Each host attaches a ``RemoteSink`` so its drained events
stream over a real socket into one ``IngestServer``; a single
``ProfileSession`` over the server's ``FleetSource`` folds the merged
fleet stream and reports the bottleneck with host provenance — the text
profile gains per-host lanes, and the critical path points at the serial
section on ``db-1`` without instrumenting the lock.

Run:  PYTHONPATH=src python examples/fleet_profile.py
"""
import threading
import time

from repro.core import ProfileSession
from repro.fleet import IngestServer, attach_remote


def run_host(host_id: str, server_addr, serial: bool) -> None:
    s = ProfileSession(n_min=None, dt=0.001)
    lock = threading.Lock()
    wids = [s.register_worker(f"worker{i}") for i in range(4)]
    sink = attach_remote(s, server_addr, host_id=host_id, clock_offset_ns=0)

    def worker(i):
        for _ in range(8):
            with s.span(wids[i], "parallel_compute"):
                time.sleep(0.003)
            if serial and i == 0:
                with s.span(wids[i], "commit_txn"):
                    with lock:
                        time.sleep(0.010)

    with s.running():
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    s.result()
    sink.close()


def main():
    server = IngestServer()                 # 127.0.0.1:<ephemeral>
    server.start()
    fleet = ProfileSession(server.source, n_min=2.0)
    fleet.start()

    hosts = [threading.Thread(target=run_host,
                              args=(name, server.address, name == "db-1"))
             for name in ("web-0", "db-1")]
    for t in hosts:
        t.start()
    for t in hosts:
        t.join()
    assert server.wait_idle(10.0), server.stats()

    rep = fleet.result()
    server.close()
    print(fleet.export("text", max_paths=3))
    print(f"hosts ingested: {rep.hosts}")
    per_host = rep.per_host()
    worst = max(per_host, key=lambda h: per_host[h]["critical_cm_s"])
    top = rep.path_str(rep.paths[0]) if rep.paths else "<none>"
    assert rep.hosts == ["web-0", "db-1"] or rep.hosts == ["db-1", "web-0"]
    print(f"\n=> most critical host: {worst}; top path: {top}")
    assert "commit_txn" in top, top


if __name__ == "__main__":
    main()
