"""Quickstart: profile a multithreaded workload 'out of the box'.

Four worker threads do parallel work, but every iteration one of them also
holds a shared resource (a lock-protected section) three times longer than
the parallel phase — a synthetic Bodytrack (paper §5.2).  GAPP needs no
instrumentation of the lock itself: the span tracer + CMetric rank the
serial section as the top bottleneck and the sampling probe attributes it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import threading
import time

from repro.core import Gapp, render_text


def main():
    gapp = Gapp(n_min=None, dt=0.001)       # n_min defaults to workers/2
    lock = threading.Lock()
    n_threads = 4
    wids = [gapp.register_worker(f"worker{i}") for i in range(n_threads)]

    def worker(i):
        for it in range(10):
            with gapp.span(wids[i], "parallel_compute"):
                time.sleep(0.004)
            # only worker 0 writes the shared output file (the bottleneck)
            if i == 0:
                with gapp.span(wids[i], "write_output"):
                    with lock:
                        time.sleep(0.012)

    with gapp.running():
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    rep = gapp.report()
    print(render_text(rep, max_paths=3))
    top = rep.path_str(rep.paths[0])
    assert "write_output" in top, f"expected write_output, got {top}"
    print("\n=> GAPP pinpointed the serial section:", top)


if __name__ == "__main__":
    main()
