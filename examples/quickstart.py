"""Quickstart: profile a multithreaded workload 'out of the box'.

Four worker threads do parallel work, but every iteration one of them also
holds a shared resource (a lock-protected section) three times longer than
the parallel phase — a synthetic Bodytrack (paper §5.2).  GAPP needs no
instrumentation of the lock itself: the streaming ``ProfileSession`` drains
and folds events in the background *while the threads run*, pushes live
top-1 updates through ``watch()``, and the final report ranks the serial
section first with the sampling probe attributing it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import threading
import time

from repro.core import ProfileSession


def main():
    s = ProfileSession(n_min=None, dt=0.001)    # n_min defaults to workers/2
    lock = threading.Lock()
    n_threads = 4
    wids = [s.register_worker(f"worker{i}") for i in range(n_threads)]

    # live push: the background drain worker delivers an incremental report
    # every 50 ms without stopping the workload
    updates = []
    s.watch(lambda rep: updates.append(
        rep.path_str(rep.paths[0]) if rep.paths else "<warming up>"),
        every=0.05, top_n=1)

    def worker(i):
        for it in range(10):
            with s.span(wids[i], "parallel_compute"):
                time.sleep(0.004)
            # only worker 0 writes the shared output file (the bottleneck)
            if i == 0:
                with s.span(wids[i], "write_output"):
                    with lock:
                        time.sleep(0.012)

    with s.running():
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        mid = s.snapshot()          # incremental report, capture still live

    rep = s.result()
    print(s.export("text", max_paths=3))
    print(f"live updates pushed while running: {len(updates)} "
          f"(last: {updates[-1] if updates else '-'})")
    print(f"mid-capture snapshot already saw {mid.total_slices} slices")
    top = rep.path_str(rep.paths[0])
    assert "write_output" in top, f"expected write_output, got {top}"
    print("\n=> GAPP pinpointed the serial section:", top)


if __name__ == "__main__":
    main()
