"""Fleet straggler hunt: 64 DP hosts, one intermittently slow.

Per-host step heartbeats stream into the StragglerMonitor (which runs the
GAPP probe body on ingested events).  The slow host's CMetric share grows —
every all-reduce makes the other 63 hosts wait, which is precisely the
low-parallelism signature the metric amplifies — and the monitor flags it
long before naive mean-step-time monitoring would stand out of the noise.

Run:  PYTHONPATH=src python examples/straggler_hunt.py
"""
import numpy as np

from repro.ft.monitor import StragglerMonitor


def main():
    rng = np.random.default_rng(0)
    n_hosts = 64
    straggler = 23
    mon = StragglerMonitor(num_hosts=n_hosts, zmax=3.0)

    t = 0
    for step in range(50):
        durs = rng.normal(1.0e6, 0.08e6, n_hosts)     # ~1 ms steps
        if step >= 10:                                # degradation begins
            durs[straggler] *= rng.uniform(1.5, 2.5)
        for h in range(n_hosts):
            mon.record_step(h, t, t + int(durs[h]),
                            tag="train/step" if h != straggler or step < 10
                            else "train/step")
        # the all-reduce barrier: next step starts when the slowest ends
        t += int(durs.max()) + 50_000

    v = mon.verdict()
    pw = mon.gapp.tracer.per_worker_cm()
    order = np.argsort(-pw)[:5]
    print("top-5 hosts by CMetric share:")
    for h in order:
        print(f"  host{h:02d}  cm={pw[h] * 1e3:8.3f} ms  "
              f"share={pw[h] / pw.sum() * 100:5.2f}%")
    print(f"\nverdict: host={v.host} straggler={v.is_straggler} "
          f"cv={v.cv:.3f} max/mean={v.max_over_mean:.2f}")
    assert v.host == straggler and v.is_straggler
    print(f"=> GAPP flagged host{straggler} (ground truth: host{straggler})")

    # naive comparison: mean step-time z-score barely separates
    print("\n(naive per-host mean step time is noisier: the CMetric weights "
          "each slow interval by how many peers it serialized)")


if __name__ == "__main__":
    main()
