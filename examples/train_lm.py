"""End-to-end driver: train a ~100M-param LM with the profiler attached.

Phase 1 trains normally; phase 2 injects a slow data loader (the classic
fleet bottleneck).  The GAPP profile shifts: phase-2 critical paths move
from compute spans to ``train/wait_data``, and the per-worker chart shows
the loader dominating — the paper's workflow ("rank, read the top path,
fix that") on a real training loop with checkpointing and prefetch.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--dmodel 768]
(defaults produce a ~110M-param llama-style model; use --steps 40
--dmodel 256 for a quick pass on a small CPU.)
"""
import argparse

import jax

from repro.core import ProfileSession, render_text
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.train.step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def build_cfg(d_model: int) -> ModelConfig:
    return ModelConfig(
        name=f"lm-{d_model}", family="dense",
        num_layers=12, d_model=d_model, num_heads=d_model // 64,
        num_kv_heads=d_model // 64, d_ff=4 * d_model, vocab_size=32000,
        block_pattern=("dense",),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dmodel", type=int, default=768)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = build_cfg(args.dmodel)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}, ~{n_params / 1e6:.0f}M params")

    opt_cfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=20,
                                total_steps=args.steps)
    gapp = ProfileSession(dt=0.002)
    half = args.steps // 2
    tcfg = TrainerConfig(steps=half, batch_per_host=args.batch,
                         seq_len=args.seq, ckpt_every=max(half // 2, 1),
                         ckpt_dir="/tmp/repro_example_ckpt",
                         log_every=20)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    print("== phase 1: healthy pipeline ==")
    t1 = Trainer(cfg, opt_cfg, tcfg, gapp=gapp, step_fn=step_fn)
    t1.run()
    rep1 = t1.profile_report()
    print(render_text(rep1, max_paths=3))

    # size the injected stall relative to the measured step time so the
    # demo works on any host speed (1.5x the phase-1 mean step)
    step_s = t1.gapp.tracer.per_worker_cm()[t1.w_train] \
        / max(len(t1.history), 1)
    delay = max(1.5 * step_s, 0.05)
    print(f"== phase 2: slow data loader injected ({delay * 1e3:.0f}ms/batch,"
          f" 1.5x the {step_s * 1e3:.0f}ms phase-1 step) ==")
    gapp2 = ProfileSession(dt=0.002)
    tcfg2 = TrainerConfig(steps=half, batch_per_host=args.batch,
                          seq_len=args.seq, ckpt_every=max(half // 2, 1),
                          ckpt_dir="/tmp/repro_example_ckpt2",
                          log_every=20, loader_delay_s=delay)
    t2 = Trainer(cfg, opt_cfg, tcfg2, gapp=gapp2, step_fn=step_fn)
    t2.run()
    rep2 = t2.profile_report()
    print(render_text(rep2, max_paths=3))

    losses = [h["loss"] for h in t1.history]
    print(f"loss: start {losses[0]:.3f} -> end {losses[-1]:.3f} "
          f"(decreased: {losses[-1] < losses[0]})")
    top2 = rep2.path_str(rep2.paths[0]) if rep2.paths else "?"
    print(f"phase-2 top bottleneck path: {top2}")
    hit = any("data/generate" in rep2.path_str(p)
              for p in rep2.paths[:2])
    print("=> GAPP attributed the slowdown to the data pipeline:", hit)


if __name__ == "__main__":
    main()
