"""MoE expert imbalance as a serialization bottleneck.

Experts are logical workers.  We run the *real* tiny-arctic router on a
skewed token distribution, convert each expert's per-layer load into busy
spans (service time ∝ tokens processed, experts process in parallel, the
all-to-all completes when the slowest expert finishes), and profile.  The
hot expert's CMetric share exposes the imbalance; with the router's
aux-loss-balanced load the profile flattens and step time drops.

Run:  PYTHONPATH=src python examples/moe_imbalance.py
"""
import dataclasses

import jax
import numpy as np

from repro import configs
from repro.core import ProfileSession, imbalance_stats
from repro.models import moe as moe_lib


def expert_loads(skew: float, seed: int = 0):
    """Run the tiny-arctic router on inputs biased toward one direction."""
    cfg = configs.get_tiny("arctic-480b")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    p = moe_lib.init_moe(jax.random.PRNGKey(1), cfg)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (4, 64, cfg.d_model), cfg.compute_dtype)
    if skew > 0:
        bias = jax.random.normal(jax.random.PRNGKey(9), (cfg.d_model,))
        x = x + skew * bias          # pushes the router toward few experts
    _, aux = moe_lib.moe_ffn(p, x, cfg)
    return np.asarray(aux["expert_load"], np.int64), cfg.num_experts


def profile_loads(loads: np.ndarray, steps: int = 20,
                  ns_per_token: int = 2000):
    g = ProfileSession(n_min=None)
    wids = [g.register_worker(f"expert{e}", "expert")
            for e in range(len(loads))]
    t = 0
    for _ in range(steps):
        for e in range(len(loads)):
            if loads[e] > 0:
                # per-expert tags: the profile (and the what-if engine)
                # can name exactly which expert serializes the all-to-all
                g.ingest(t, wids[e], +1, f"moe/expert{e}")
        dur = loads * ns_per_token
        for e in np.argsort(dur):
            if loads[e] > 0:
                g.ingest(t + int(dur[e]), wids[int(e)], -1)
        t += int(dur.max()) + 10_000     # all-to-all barrier
    return g, t


def main():
    for name, skew in (("balanced", 0.0), ("skewed", 2.5)):
        loads, ne = expert_loads(skew)
        g, span = profile_loads(loads)
        pw = g.tracer.per_worker_cm()
        stats = imbalance_stats(pw)
        hot = int(np.argmax(pw))
        print(f"{name:9s} loads[min/max]={loads.min()}/{loads.max()} "
              f"cm_cv={stats['cv']:.2f} hot=expert{hot} "
              f"hot_share={pw[hot] / max(pw.sum(), 1e-12) * 100:.1f}% "
              f"step_span={span / 20 / 1e6:.2f} ms")
    print("\n=> the hot expert serializes every all-to-all; its CMetric "
          "share is the profiler's native view of router imbalance. "
          "The trainer exports expert_load each step, so this profile is "
          "available live during training.")

    # causal what-if vs constructible ground truth: project the gain from
    # dropping the hot expert's work, then *measure* it by re-profiling
    # with that expert's load zeroed — the projection must match
    loads, ne = expert_loads(2.5)
    g, _ = profile_loads(loads)
    rep = g.result()
    hot = int(np.argmax(rep.per_worker))
    wi = rep.what_if(f"moe/expert{hot}", shrink=0.0)
    fixed = loads.copy()
    fixed[hot] = 0
    g2, _ = profile_loads(fixed)
    actual = rep.total_time / g2.result().total_time
    err = abs(wi.speedup - actual) / actual
    print(f"\nwhat-if: drop expert{hot} -> projected {wi.speedup:.3f}x "
          f"end-to-end; measured without it {actual:.3f}x "
          f"(error {err * 100:.1f}%)")


if __name__ == "__main__":
    main()
