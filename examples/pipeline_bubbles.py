"""Pipeline-parallel bubbles through the profiler's lens.

The GPipe schedule's warm-up/drain slots are reduced-parallelism intervals.
Ingesting the schedule's per-stage busy intervals, the CMetric splits
cleanly: with few microbatches the bubble fraction is large and stage
criticality is heavily skewed toward the pipeline ends; scaling microbatches
flattens it.  The same numbers fall out of the profiler as from the
analytic bubble formula (n_stages-1)/(n_micro+n_stages-1).

Run:  PYTHONPATH=src python examples/pipeline_bubbles.py
"""

from repro.core import ProfileSession, imbalance_stats
from repro.pipeline.gpipe import schedule_intervals


def profile_schedule(n_stages: int, n_micro: int,
                     serial_update_ns: int = 0):
    g = ProfileSession(n_min=None)
    wids = [g.register_worker(f"stage{s}", "stage") for s in range(n_stages)]
    events = []
    for s, t0, t1 in schedule_intervals(n_stages, n_micro, t_stage=1e-3):
        # integer ns (float accumulation would mis-order end/start ties)
        events.append((round(t0 * 1e9), s, +1))
        events.append((round(t1 * 1e9), s, -1))
    for t, s, d in sorted(events):
        g.ingest(t, wids[s], d, "stage_step")
    if serial_update_ns:
        # injected bottleneck with ground truth by construction: a serial
        # optimizer step on stage0 after the pipeline drains — removing
        # it is worth exactly serial_update_ns of wall clock
        t_end = max(t for t, _, _ in events)
        g.ingest(t_end, wids[0], +1, "optimizer/serial_update")
        g.ingest(t_end + int(serial_update_ns), wids[0], -1)
    pw = g.tracer.per_worker_cm()
    span = (n_stages + n_micro - 1) * 1e-3
    busy = n_stages * n_micro * 1e-3
    bubble = 1 - busy / (span * n_stages)
    return pw, bubble, g


def main():
    n_stages = 8
    print(f"{'n_micro':>8s} {'bubble%':>8s} {'cm_cv':>8s} "
          f"{'cm(stage0)':>11s} {'cm(mid)':>9s}")
    for n_micro in (2, 4, 8, 16, 32, 64):
        pw, bubble, _ = profile_schedule(n_stages, n_micro)
        stats = imbalance_stats(pw)
        print(f"{n_micro:8d} {bubble * 100:8.1f} {stats['cv']:8.3f} "
              f"{pw[0] * 1e3:11.3f} {pw[n_stages // 2] * 1e3:9.3f}")
    print("\n=> bubbles shrink as microbatches grow; the CMetric CV tracks "
          "the bubble fraction, and the profiler needs no schedule "
          "knowledge to see it.")
    # the profiler's idle+criticality accounting matches the analytic bubble
    pw, bubble, g = profile_schedule(8, 8)
    total = g.tracer.per_worker_cm().sum() + g.tracer.idle_time
    span = (8 + 8 - 1) * 1e-3
    assert abs(total - span) < 1e-6
    print(f"   (conservation check: Σcm+idle = {total * 1e3:.3f} ms "
          f"= schedule span {span * 1e3:.3f} ms)")
    # causal what-if: inject a 2 ms serial optimizer step and ask what
    # fixing it is worth — the true gain is its duration, by construction
    serial_ns = 2_000_000
    _, _, g = profile_schedule(8, 8, serial_update_ns=serial_ns)
    rep = g.result()
    wi = rep.what_if("optimizer/serial_update", shrink=0.0)
    truth_s = rep.total_time - serial_ns / 1e9
    print(f"\nwhat-if: remove the {serial_ns / 1e6:.2f} ms serial "
          f"optimizer step -> projected {wi.speedup:.3f}x "
          f"({rep.total_time * 1e3:.2f} -> {wi.projected_total_s * 1e3:.2f} "
          f"ms); ground truth {truth_s * 1e3:.2f} ms")
    assert abs(wi.projected_total_s - truth_s) < 1e-9, (
        wi.projected_total_s, truth_s)


if __name__ == "__main__":
    main()
